//! The session API: one [`Fabric`], many tenants, nonblocking
//! collectives.
//!
//! Before this module, the application surface was one-shot:
//! `run_collective` silently built and tore down a whole fabric per
//! call, every example hand-assembled `Cluster`/`SdnController`/
//! `MemClient`, and two jobs could never share devices. A DNN training
//! framework does not work that way: it holds a communicator per job,
//! streams many small bucketed gradient tensors, and overlaps
//! communication with compute (NetReduce, and the FPGA AI-SmartNIC
//! line of work). This module is that front door:
//!
//! * [`Fabric`] — built **once** by [`FabricBuilder`]: topology +
//!   instruction registry + DES engine, optionally the §2.6 pool
//!   controller. It owns the shared
//!   [`EngineSession`](crate::transport::EngineSession), so every
//!   in-flight operation — collectives from any communicator and
//!   pooled-memory batches alike — multiplexes onto one completion
//!   hook with per-slot windows instead of serialized fabric rebuilds.
//! * [`Communicator`] — a per-tenant handle carrying rank identity and
//!   a private device-memory region. Ops are **nonblocking**:
//!   [`iallreduce`](Communicator::iallreduce) /
//!   [`ireduce_scatter`](Communicator::ireduce_scatter) /
//!   [`iallgather`](Communicator::iallgather) /
//!   [`ibcast`](Communicator::ibcast) /
//!   [`ireduce`](Communicator::ireduce) return a redeemable
//!   [`CollectiveHandle`]; [`Fabric::wait`] drives the shared DES until
//!   that op (and any concurrent neighbors) completes.
//! * **Gradient bucketing** — [`plan_buckets`] packs a stream of small
//!   tensors into interleave-block-sized buckets and
//!   [`Communicator::iallreduce_buckets`] lowers each bucket as one
//!   collective, so tiny tensors stop paying a full per-op schedule
//!   (the NetReduce / Horovod fusion-buffer trick).
//! * **Memory plane on the same session** — [`Fabric::submit_mem`]
//!   submits a [`MemBatch`] plan into the shared session;
//!   [`Fabric::wait_mem`] redeems it. A NAK in one tenant's plan
//!   cancels *only that plan* — the engine's per-plan cancellation —
//!   while neighbors keep flowing.
//!
//! Concurrency contract: ops submitted on one fabric run concurrently
//! in simulated time. Two ops that write the **same** region (e.g. two
//! `iallreduce` over one communicator range) must not be in flight
//! together — use disjoint ranges (buckets) or wait between them.
//! Distinct communicators always use disjoint regions.
//!
//! `run_collective(AlgoKind, &RunOpts)` is now a compatibility shim
//! over a single-use `Fabric` (see `collectives::driver`).

use anyhow::{bail, ensure, Result};

use crate::collectives::driver::{
    lower_schedule, CollectiveAlgorithm, CollectiveSpec, Phase, PlanCtx, TopoFacts,
};
use crate::collectives::{AlgoKind, CollectiveReport};
use crate::iommu::Perms;
use crate::isa::registry::MemAccess;
use crate::mem::{BatchResult, MemBatch, MemClient, MemError, PreparedMemPlan};
use crate::net::{
    Cluster, DeviceProfile, EcmpMode, LinkConfig, NodeId, ShardPartition, ShardedRuntime, Topology,
};
use crate::pool::{Allocation, IommuDirectory, InterleaveMap, SdnController, TenantId};
use crate::sim::{Engine, SimTime};
use crate::transport::{CcMode, EngineSession, PlanId, ReliabilityTable, TokenBucket};
use crate::util::stats::percentile_ns;
use crate::wire::DeviceIp;

/// The pool/IOMMU granule this fabric programs (the paper's 8 KiB
/// interleave block).
const GRANULE: u64 = 8192;

fn round_up(v: u64, to: u64) -> u64 {
    v.div_ceil(to) * to
}

// ------------------------------------------------------------- builder

/// Which physical fabric to build.
#[derive(Debug, Clone, Copy)]
pub enum FabricTopology {
    /// `ranks` devices (+ hosts) on one ToR switch — the paper testbed.
    Star,
    /// Two-level Clos (`pods × devs_per_leaf` devices, `spines` spines).
    FatTree {
        pods: usize,
        devs_per_leaf: usize,
        spines: usize,
    },
    /// Two leaves × two spines, everything dual-homed (E4's fabric).
    DualSpine { devs_per_leaf: usize },
}

/// Builds a [`Fabric`] once; see the module docs.
pub struct FabricBuilder {
    topology: FabricTopology,
    ranks: usize,
    hosts: usize,
    seed: u64,
    link: LinkConfig,
    profile: DeviceProfile,
    ecmp: EcmpMode,
    window: usize,
    reliable: bool,
    loss_p: f64,
    pool_bytes: u64,
    shards: usize,
    shard_threads: usize,
    partition: ShardPartition,
    cc: CcMode,
}

impl Default for FabricBuilder {
    fn default() -> Self {
        Self {
            topology: FabricTopology::Star,
            ranks: 4,
            hosts: 0,
            seed: 0xFAB0,
            link: LinkConfig::dc_100g(),
            profile: DeviceProfile::Data,
            ecmp: EcmpMode::FlowHash,
            window: 16,
            reliable: false,
            loss_p: 0.0,
            pool_bytes: 0,
            shards: 0,
            shard_threads: 0,
            partition: ShardPartition::Modulo,
            cc: CcMode::Static,
        }
    }
}

impl FabricBuilder {
    /// Star fabric with `ranks` devices.
    pub fn star(mut self, ranks: usize) -> Self {
        self.topology = FabricTopology::Star;
        self.ranks = ranks;
        self
    }

    /// Two-level Clos fabric (ranks = `pods × devs_per_leaf`).
    pub fn fat_tree(mut self, pods: usize, devs_per_leaf: usize, spines: usize) -> Self {
        self.topology = FabricTopology::FatTree {
            pods,
            devs_per_leaf,
            spines,
        };
        self
    }

    /// E4's dual-spine fabric (ranks = `2 × devs_per_leaf`).
    pub fn dual_spine(mut self, devs_per_leaf: usize) -> Self {
        self.topology = FabricTopology::DualSpine { devs_per_leaf };
        self
    }

    /// The canonical topology for a device collective: the two-level
    /// planners (hierarchical, switch-reduce) run on the 2-pod
    /// fat-tree, everything else on a star — the one place the
    /// `run_collective` shim and the E2 coordinator share.
    pub fn for_algo(self, kind: AlgoKind, ranks: usize) -> Result<Self> {
        Ok(
            if matches!(kind, AlgoKind::Hierarchical | AlgoKind::SwitchReduce) {
                ensure!(
                    ranks >= 4 && ranks % 2 == 0,
                    "{} needs an even rank count >= 4",
                    kind.name()
                );
                self.fat_tree(2, ranks / 2, 2)
            } else {
                self.star(ranks)
            },
        )
    }

    /// Plain hosts attached to the switch (star only; pooled-memory
    /// tenants each need one).
    pub fn hosts(mut self, n: usize) -> Self {
        self.hosts = n;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn link(mut self, link: LinkConfig) -> Self {
        self.link = link;
        self
    }

    /// Phantom payloads (timing-only devices) for paper-scale vectors.
    pub fn timing_only(mut self, on: bool) -> Self {
        self.profile = if on {
            DeviceProfile::TimingOnly
        } else {
            DeviceProfile::Data
        };
        self
    }

    pub fn ecmp(mut self, mode: EcmpMode) -> Self {
        self.ecmp = mode;
        self
    }

    /// Default per-slot in-flight window for the shared session.
    pub fn window(mut self, window: usize) -> Self {
        self.window = window.max(1);
        self
    }

    /// Timeout-retransmit tracking for every communicator op.
    pub fn reliable(mut self, on: bool) -> Self {
        self.reliable = on;
        self
    }

    /// Per-wire loss probability (fault injection).
    pub fn loss(mut self, p: f64) -> Self {
        self.loss_p = p;
        self
    }

    /// Run the DES on the sharded parallel core with `n` shards (see
    /// `sim::sharded` / `net::shard`): the world is partitioned by node,
    /// each shard owns its own event heap and local clock, and shards
    /// advance in bounded windows under the fabric's conservative
    /// lookahead. Same seed ⇒ bit-identical reports at *any* shard
    /// count — `with_shards(1)` runs the same partitioned core on one
    /// shard, so lossy runs stay comparable across shard counts (the
    /// sharded core draws loss/jitter from per-link RNG streams, not the
    /// classic engine's single sequential stream). `n = 0` (the
    /// default) keeps the classic single-heap engine.
    pub fn with_shards(mut self, n: usize) -> Self {
        self.shards = n;
        self
    }

    /// Worker threads for the sharded core (`0` = pick from available
    /// parallelism; `1` forces serial execution — results are identical
    /// either way).
    pub fn shard_threads(mut self, n: usize) -> Self {
        self.shard_threads = n;
        self
    }

    /// How the sharded core maps nodes onto shards (see
    /// [`ShardPartition`]). [`ShardPartition::Pods`] keeps each
    /// fat-tree pod — its devices and leaf switch — on one shard, so
    /// intra-pod traffic stays shard-local and only spine hops cross
    /// the channel mesh; on topologies without pods it falls back to
    /// the default modulo striping. Results are bit-identical under
    /// either mapping (the determinism contract partitions *work*, not
    /// *behavior*).
    pub fn shard_partition(mut self, mode: ShardPartition) -> Self {
        self.partition = mode;
        self
    }

    /// Congestion control for the shared session. [`CcMode::Dcqcn`]
    /// gives every window slot a closed-loop rate controller fed by
    /// CE-marked completions (switch RED marks echoed by the device):
    /// collectives and [`MemBatch`] plans get adaptive pacing with zero
    /// call-site changes. Under DCQCN, collective ops charge their wire
    /// bytes to the pacer (normally they self-clock unpaced). The
    /// default, [`CcMode::Static`], keeps static budgets only.
    pub fn with_congestion_control(mut self, cc: CcMode) -> Self {
        self.cc = cc;
        self
    }

    /// Enable the §2.5/§2.6 memory pool with `per_device_bytes` of
    /// poolable memory per device. Communicator regions are carved
    /// *above* the pool share, and on a pooled fabric every communicator
    /// region is IOMMU-mapped (un-leased, RW) so collective programs
    /// keep translating once the devices latch into enforcing mode.
    pub fn with_pool(mut self, per_device_bytes: u64) -> Self {
        self.pool_bytes = per_device_bytes;
        self
    }

    /// Build the fabric: topology, routes, reliability table, fault
    /// injection, the shared engine session, and (optionally) the pool
    /// controller.
    pub fn build(self) -> Result<Fabric> {
        let topo = match self.topology {
            FabricTopology::Star => Topology::star_with(
                self.seed,
                self.ranks,
                self.hosts,
                self.link.clone(),
                self.profile,
            ),
            FabricTopology::FatTree {
                pods,
                devs_per_leaf,
                spines,
            } => Topology::fat_tree_with(
                self.seed,
                pods,
                devs_per_leaf,
                spines,
                self.link.clone(),
                self.ecmp,
                self.profile,
            ),
            FabricTopology::DualSpine { devs_per_leaf } => {
                Topology::dual_spine(self.seed, devs_per_leaf, self.link.clone(), self.ecmp)
            }
        };
        let mut cl = topo.cluster;
        let devices = topo.devices;
        let hosts = topo.hosts;
        let switches = topo.switches;
        let facts = TopoFacts {
            leaf_groups: topo.leaf_groups,
            leaf_ips: topo.leaf_ips,
            spine_ips: topo.spine_ips,
        };
        ensure!(!devices.is_empty(), "a fabric needs at least one device");
        let ips: Vec<DeviceIp> = devices.iter().map(|&d| cl.device(d).ip()).collect();
        let device_capacity = cl.device(devices[0]).mem_ref().capacity();
        if self.reliable {
            // Chains take ~10 us idle but queue under load; a generous
            // timeout avoids spurious (harmless but wasteful) duplicates.
            cl.xport = ReliabilityTable::new(2_000_000, 12);
        }
        if self.loss_p > 0.0 {
            cl.fault.loss_p = self.loss_p;
        }
        let controller = if self.pool_bytes > 0 {
            ensure!(
                !hosts.is_empty(),
                "a pooled fabric needs at least one host (FabricBuilder::hosts)"
            );
            ensure!(
                self.pool_bytes % GRANULE == 0,
                "pool share must be a multiple of the {GRANULE} B interleave block"
            );
            let map = InterleaveMap::paper_default(ips.clone());
            Some(SdnController::new(map, self.pool_bytes))
        } else {
            None
        };
        // Communicator regions live above the pool's per-device share.
        let region_cursor = if controller.is_some() {
            self.pool_bytes
        } else {
            0
        };
        ensure!(
            region_cursor < device_capacity,
            "pool share exhausts the device capacity"
        );
        // The sharded core snapshots routes now (topology is final) and
        // flips the cluster into capture mode: session injections are
        // recorded and replayed into the shards on each drive round.
        let sharded = if self.shards > 0 {
            cl.capture = Some(Vec::new());
            let mut rt = ShardedRuntime::new(&cl, self.seed, self.shards, self.shard_threads);
            let is_fat_tree = matches!(self.topology, FabricTopology::FatTree { .. });
            if self.partition == ShardPartition::Pods && is_fat_tree {
                // Pod p (devices + leaf switch) → shard p mod n; spines
                // stripe separately; anything else keeps the modulo map.
                let n_nodes = cl.nodes.len();
                let mut assign: Vec<usize> =
                    (0..n_nodes).map(|i| i % self.shards).collect();
                let spines = facts.spine_ips.len();
                for (s, &sw) in switches[..spines].iter().enumerate() {
                    assign[sw] = s % self.shards;
                }
                for (p, group) in facts.leaf_groups.iter().enumerate() {
                    let shard = p % self.shards;
                    assign[switches[spines + p]] = shard;
                    for &r in group {
                        assign[devices[r]] = shard;
                    }
                }
                rt = rt.with_assignment(assign);
            }
            Some(rt)
        } else {
            None
        };
        let cc_paced = matches!(self.cc, CcMode::Dcqcn(_));
        Ok(Fabric {
            cl,
            eng: Engine::new(),
            devices,
            ips,
            hosts,
            topo: facts,
            session: EngineSession::new(self.window).with_congestion_control(self.cc),
            window: self.window,
            reliable: self.reliable,
            cc_paced,
            next_done_id: 0,
            next_tenant: 1,
            next_host: 0,
            region_cursor,
            device_capacity,
            controller,
            ops: Vec::new(),
            active_ops: Vec::new(),
            mem_plans: Vec::new(),
            sharded,
        })
    }
}

// -------------------------------------------------------------- fabric

/// A nonblocking collective in flight (or finished) on the fabric.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CollectiveHandle(usize);

/// A pooled-memory batch in flight on the fabric's session.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MemHandle(usize);

/// What a redeemed collective produced.
#[derive(Debug, Clone)]
pub struct CollectiveOutcome {
    pub algorithm: &'static str,
    pub elements: usize,
    /// Packet ops planned across all phases so far.
    pub ops: usize,
    /// Ops retired. `< ops` means the op did not converge (unrecovered
    /// loss on an unreliable fabric) — callers decide whether that is an
    /// error, exactly like the driver's contract.
    pub ops_done: usize,
    /// Simulated time the op was submitted.
    pub started_ns: SimTime,
    /// Time of the last retirement (== `started_ns` when nothing ran).
    pub finished_ns: SimTime,
    /// Per-op completion latencies (wire release → retirement, ns) from
    /// every *folded* (completed) phase — the p50/p99 lens
    /// [`Fabric::report`] summarizes.
    pub latencies: Vec<SimTime>,
}

impl CollectiveOutcome {
    pub fn complete(&self) -> bool {
        self.ops_done == self.ops
    }

    /// Wall time the op spent on the fabric.
    pub fn elapsed_ns(&self) -> SimTime {
        self.finished_ns.saturating_sub(self.started_ns)
    }
}

/// One nonblocking collective's state machine: phases are planned
/// lazily — phase `k+1` is planned (against live device memory) only
/// once phase `k`'s plan retired, mirroring the driver's inter-phase
/// barrier without stopping anyone else's traffic.
struct OpState {
    algorithm: &'static str,
    algo: Box<dyn CollectiveAlgorithm>,
    spec: CollectiveSpec,
    phases: usize,
    next_phase: usize,
    /// The *current* phase's session plan. Completed phases are folded
    /// into `done_prior`/`last_prior` and released back to the session's
    /// plan slab, so a long-lived op holds at most one live plan — the
    /// session's footprint tracks concurrency, not history.
    plan: Option<PlanId>,
    /// Ops retired by already-released (completed) phase plans.
    done_prior: usize,
    /// Latest retirement time among released phase plans.
    last_prior: SimTime,
    ops_total: usize,
    /// Per-op completion latencies folded from released phase plans.
    latencies: Vec<SimTime>,
    started_at: SimTime,
    finished_at: Option<SimTime>,
    /// A phase stopped short (loss beyond retries / cancellation);
    /// later phases would compute on stale data, so the op is parked.
    stalled: bool,
}

struct MemPlanState {
    plan: Option<PlanId>,
    prepared: Option<PreparedMemPlan>,
}

/// The long-lived fabric a training framework would link against; see
/// the module docs. Built once, shared by every tenant.
pub struct Fabric {
    cl: Cluster,
    eng: Engine<Cluster>,
    devices: Vec<NodeId>,
    ips: Vec<DeviceIp>,
    hosts: Vec<NodeId>,
    /// Topology facts handed to topology-aware planners (leaf
    /// membership, addressed leaf/spine switch IPs).
    topo: TopoFacts,
    session: EngineSession,
    window: usize,
    reliable: bool,
    /// DCQCN is active: collective ops charge wire bytes to the pacer
    /// (see `lower_schedule`'s `paced` flag).
    cc_paced: bool,
    next_done_id: u32,
    next_tenant: TenantId,
    next_host: usize,
    region_cursor: u64,
    device_capacity: u64,
    controller: Option<SdnController>,
    ops: Vec<OpState>,
    /// Indices of ops that still have phases to advance (finished and
    /// stalled ops drop off).
    active_ops: Vec<usize>,
    mem_plans: Vec<MemPlanState>,
    /// The sharded parallel DES core, when the builder asked for it.
    /// `None` runs the classic single-heap engine.
    sharded: Option<ShardedRuntime>,
}

impl Fabric {
    pub fn builder() -> FabricBuilder {
        FabricBuilder::default()
    }

    // ------------------------------------------------------- accessors

    pub fn ranks(&self) -> usize {
        self.devices.len()
    }

    pub fn devices(&self) -> &[NodeId] {
        &self.devices
    }

    pub fn ips(&self) -> &[DeviceIp] {
        &self.ips
    }

    pub fn hosts(&self) -> &[NodeId] {
        &self.hosts
    }

    pub fn leaf_groups(&self) -> &[Vec<usize>] {
        &self.topo.leaf_groups
    }

    /// The topology facts planners see ([`TopoFacts`]).
    pub fn topo_facts(&self) -> &TopoFacts {
        &self.topo
    }

    pub fn now(&self) -> SimTime {
        self.eng.now()
    }

    pub fn cluster(&self) -> &Cluster {
        &self.cl
    }

    /// Mutable cluster access (e.g. building a [`MemBatch`] allocates
    /// sequence numbers).
    pub fn cluster_mut(&mut self) -> &mut Cluster {
        &mut self.cl
    }

    /// Raw access for experiments that inject their own traffic (E4's
    /// spray arms) or drive a standalone engine run between fabric
    /// waits (the session releases its completion hook whenever it goes
    /// idle).
    pub fn raw_parts(&mut self) -> (&mut Cluster, &mut Engine<Cluster>) {
        (&mut self.cl, &mut self.eng)
    }

    /// High-water mark of plans simultaneously in flight on the shared
    /// session — ≥ 2 proves two tenants' ops coexisted.
    pub fn max_concurrent_plans(&self) -> usize {
        self.session.max_concurrent_plans()
    }

    /// The session's DCQCN rate trajectory: one `(slot, time,
    /// rate_gbps.to_bits())` entry per CNP, in delivery order. Bit-exact
    /// across shard counts (the determinism tests compare it verbatim);
    /// empty under [`CcMode::Static`].
    pub fn rate_log(&self) -> Vec<(usize, SimTime, u64)> {
        self.session.rate_log()
    }

    /// CNPs (CE-marked completions) the session's rate controllers have
    /// absorbed.
    pub fn cnps(&self) -> usize {
        self.session.cnps()
    }

    // --------------------------------------------------- communicators

    /// Derive a new tenant communicator owning `region_bytes` of every
    /// device's memory (rounded up to the interleave block). On a pooled
    /// fabric the region is IOMMU-mapped un-leased so collective
    /// programs keep translating alongside enforced pool leases.
    pub fn communicator(&mut self, region_bytes: u64) -> Result<Communicator> {
        ensure!(region_bytes > 0, "a communicator needs a non-empty region");
        ensure!(self.devices.len() >= 2, "collectives need at least 2 ranks");
        let len = round_up(region_bytes, GRANULE);
        let base = self.region_cursor;
        ensure!(
            base + len <= self.device_capacity,
            "communicator region [{base:#x}..+{len:#x}) exceeds device capacity {:#x}",
            self.device_capacity
        );
        if self.controller.is_some() {
            // Devices are (or will latch) enforcing: install the region
            // on every device so collective traffic stays translatable.
            let page_bits = GRANULE.trailing_zeros();
            for ip in self.ips.clone() {
                let Some(mmu) = self.cl.device_iommu(ip) else {
                    continue;
                };
                if mmu.is_identity() {
                    mmu.set_page_bits(page_bits)?;
                }
                ensure!(
                    mmu.page_size() == GRANULE,
                    "device {ip} IOMMU granule {} != pool granule {GRANULE}",
                    mmu.page_size()
                );
                mmu.map(base, base, len, Perms::RW)?;
            }
        }
        self.region_cursor = base + len;
        let tenant = self.next_tenant;
        self.next_tenant += 1;
        Ok(Communicator {
            tenant,
            base_addr: base,
            region_bytes: len,
            window: self.window,
            reliable: self.reliable,
        })
    }

    // ------------------------------------------------ collective plumbing

    /// Submit a planner as a nonblocking op: plan + inject phase 0 now,
    /// later phases as their predecessors retire (see [`OpState`]).
    pub(crate) fn submit_algo(
        &mut self,
        algo: Box<dyn CollectiveAlgorithm>,
        spec: CollectiveSpec,
    ) -> Result<CollectiveHandle> {
        let idx = self.ops.len();
        let algorithm = algo.name();
        let phases = algo.phases();
        self.ops.push(OpState {
            algorithm,
            algo,
            spec,
            phases,
            next_phase: 0,
            plan: None,
            done_prior: 0,
            last_prior: self.eng.now(),
            ops_total: 0,
            latencies: Vec::new(),
            started_at: self.eng.now(),
            finished_at: None,
            stalled: false,
        });
        if let Err(e) = self.submit_phase(idx) {
            // A rejected planner (bad shape, root out of range) must not
            // leave a zombie op that every later drive() retries.
            self.ops.pop();
            return Err(e);
        }
        self.active_ops.push(idx);
        Ok(CollectiveHandle(idx))
    }

    /// Plan and submit op `i`'s next phase onto the shared session.
    fn submit_phase(&mut self, i: usize) -> Result<()> {
        let phase = self.ops[i].next_phase;
        let spec = self.ops[i].spec.clone();
        let done_id_base = self.next_done_id;
        let planned = {
            let op = &mut self.ops[i];
            let ctx = PlanCtx {
                devices: &self.devices,
                ips: &self.ips,
                spec: &spec,
                done_id_base,
            };
            op.algo.plan_phase(&mut self.cl, &ctx, phase)?
        };
        self.ops[i].next_phase = phase + 1;
        match planned {
            Phase::Ops(ops) => {
                if ops.is_empty() {
                    return Ok(());
                }
                self.next_done_id = self
                    .next_done_id
                    .checked_add(ops.len() as u32)
                    .expect("completion id space exhausted");
                let wops = lower_schedule(
                    &mut self.cl,
                    &self.devices,
                    spec.reliable,
                    self.cc_paced,
                    ops,
                )?;
                self.ops[i].ops_total += wops.len();
                let plan = self.session.submit(
                    &mut self.cl,
                    &mut self.eng,
                    wops,
                    false,
                    spec.window,
                )?;
                debug_assert!(
                    self.ops[i].plan.is_none(),
                    "previous phase plan not folded before the next submit"
                );
                self.ops[i].plan = Some(plan);
            }
            Phase::Apps { .. } => {
                bail!("host-baseline planners cannot run on a fabric session")
            }
        }
        Ok(())
    }

    /// Advance every *active* multi-phase op whose current phase
    /// retired; returns whether anything new was submitted. Finished and
    /// stalled ops drop off the active list so a long-lived fabric's
    /// drive cost tracks its concurrency, not its history.
    fn advance(&mut self) -> Result<bool> {
        let mut submitted = false;
        let mut result = Ok(());
        let active = std::mem::take(&mut self.active_ops);
        let mut still: Vec<usize> = Vec::with_capacity(active.len());
        for i in active {
            while result.is_ok() {
                if self.ops[i].finished_at.is_some() || self.ops[i].stalled {
                    break;
                }
                let ready = match self.ops[i].plan {
                    None => true,
                    Some(p) => {
                        if self.session.is_complete(p) {
                            // Fold the completed phase into the op's
                            // counters and release its slab slot — the
                            // session's footprint stays O(live plans).
                            let (d, _, t) = self.session.progress(p);
                            self.ops[i].done_prior += d;
                            self.ops[i].last_prior = self.ops[i].last_prior.max(t);
                            let lats = self.session.take_latencies(p);
                            self.ops[i].latencies.extend(lats);
                            self.session
                                .release(p)
                                .expect("a complete plan is releasable");
                            self.ops[i].plan = None;
                            true
                        } else {
                            if self.session.is_settled(p) {
                                // Short phase: later phases would compute
                                // on stale data (the driver breaks here
                                // too).
                                self.ops[i].stalled = true;
                            }
                            false
                        }
                    }
                };
                if !ready {
                    break;
                }
                if self.ops[i].next_phase >= self.ops[i].phases {
                    // Completed phases were folded on release, so the
                    // finish time is the latest folded retirement.
                    self.ops[i].finished_at = Some(self.ops[i].last_prior);
                    break;
                }
                match self.submit_phase(i) {
                    Ok(()) => submitted = true,
                    Err(e) => {
                        // Park the op so later drives don't re-fail on
                        // it and poison unrelated tenants' waits.
                        self.ops[i].stalled = true;
                        result = Err(e);
                    }
                }
            }
            if self.ops[i].finished_at.is_none() && !self.ops[i].stalled {
                still.push(i);
            }
        }
        self.active_ops = still;
        result.map(|()| submitted)
    }

    /// One DES pass: classic runs the single-heap engine dry; sharded
    /// drains the captured injections into the partitioned core, which
    /// runs to quiescence (firing the session's completion hook at
    /// window barriers) and advances the engine clock to match.
    fn drive_engine(&mut self) {
        match self.sharded.as_mut() {
            None => self.session.drive(&mut self.cl, &mut self.eng),
            Some(rt) => loop {
                let injected = match self.cl.capture.as_mut() {
                    Some(buf) if !buf.is_empty() => std::mem::take(buf),
                    _ => break,
                };
                rt.drive(&mut self.cl, &mut self.eng, injected);
            },
        }
    }

    /// Cumulative events executed on the sharded core (`0` on the
    /// classic path, which counts inside [`Engine`] instead).
    pub fn sharded_events(&self) -> u64 {
        self.sharded.as_ref().map_or(0, |rt| rt.events)
    }

    /// High-water mark of live scheduled events on the sharded core:
    /// per-shard heap peaks summed within a drive round, maxed across
    /// rounds (`0` on the classic path — read the engine's `peak_live`
    /// there). The sharded counterpart of `Engine::peak_live` for bench
    /// metadata.
    pub fn sharded_peak_live(&self) -> u64 {
        self.sharded.as_ref().map_or(0, |rt| rt.peak_live)
    }

    /// Shards the DES runs on (`1` for the classic single-heap engine).
    pub fn shard_count(&self) -> usize {
        self.sharded.as_ref().map_or(1, ShardedRuntime::shard_count)
    }

    /// Run the shared DES until every submitted op has gone as far as it
    /// can: drive, advance multi-phase ops, repeat until quiescent.
    pub fn drive(&mut self) -> Result<()> {
        let result = loop {
            self.drive_engine();
            match self.advance() {
                Ok(true) => continue,
                Ok(false) => break Ok(()),
                Err(e) => {
                    // Drain whatever the failed advance left in flight
                    // before surfacing the error.
                    self.drive_engine();
                    break Err(e);
                }
            }
        };
        // The DES is drained: no event can deliver another completion,
        // so release the hook unconditionally (even if an unreliable op
        // was lost and stranded in flight) — standalone engine users (a
        // raw MemClient op between waits) can run, and the next submit
        // re-installs it.
        self.session.close(&mut self.cl);
        result
    }

    /// Drive until `h` finishes and redeem its outcome. Concurrent ops
    /// from other tenants progress on the same DES run. An op that
    /// stopped short (loss beyond retries) returns `ops_done < ops`
    /// rather than an error — the driver's reporting contract.
    pub fn wait(&mut self, h: CollectiveHandle) -> Result<CollectiveOutcome> {
        self.drive()?;
        self.outcome(h)
    }

    /// The op's current outcome without driving (nonblocking poll).
    pub fn outcome(&self, h: CollectiveHandle) -> Result<CollectiveOutcome> {
        let op = &self.ops[h.0];
        let mut done = op.done_prior;
        let mut last = op.started_at.max(op.last_prior);
        if let Some(p) = op.plan {
            let (d, _, t) = self.session.progress(p);
            done += d;
            last = last.max(t);
        }
        Ok(CollectiveOutcome {
            algorithm: op.algorithm,
            elements: op.spec.elements,
            ops: op.ops_total,
            ops_done: done,
            started_ns: op.started_at,
            finished_ns: op.finished_at.unwrap_or(last),
            latencies: op.latencies.clone(),
        })
    }

    /// Has `h` finished all phases?
    pub fn is_finished(&self, h: CollectiveHandle) -> bool {
        self.ops[h.0].finished_at.is_some()
    }

    /// Shape a redeemed outcome into the bench-facing report (drop and
    /// retransmit counters are fabric-cumulative).
    pub fn report(&self, out: &CollectiveOutcome) -> CollectiveReport {
        CollectiveReport {
            algorithm: out.algorithm,
            elements: out.elements,
            elapsed_ns: out.elapsed_ns(),
            link_drops: self.cl.metrics.counter("link_drops"),
            retransmits: self.cl.xport.retransmits,
            lat_p50_ns: percentile_ns(&out.latencies, 50.0),
            lat_p99_ns: percentile_ns(&out.latencies, 99.0),
        }
    }

    // ----------------------------------------------------- memory plane

    /// Derive a pooled-memory tenant: allocates a tenant id, binds the
    /// next free host's IP to it on every device (the §2.6 requester
    /// ACL), and returns the data-plane client. Each tenant needs its
    /// own host — build the fabric with [`FabricBuilder::hosts`].
    pub fn mem_client(&mut self) -> Result<MemClient> {
        let ctl = self
            .controller
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("fabric built without a pool (with_pool)"))?;
        ensure!(
            self.next_host < self.hosts.len(),
            "no free host for a new tenant: build the fabric with hosts({})",
            self.next_host + 1
        );
        let host = self.hosts[self.next_host];
        self.next_host += 1;
        let tenant = self.next_tenant;
        self.next_tenant += 1;
        let host_ip = self.cl.host_mut(host).ip;
        ctl.grant_host(&mut self.cl, tenant, host_ip);
        Ok(MemClient::new(host, host_ip, tenant, ctl.map().clone()).with_window(self.window))
    }

    /// Lease `bytes` of pool memory for `tenant` (programs every device
    /// IOMMU — see [`SdnController::malloc_mapped`]).
    pub fn malloc(&mut self, tenant: TenantId, bytes: u64, writable: bool) -> Result<Allocation> {
        let ctl = self
            .controller
            .as_mut()
            .ok_or_else(|| anyhow::anyhow!("fabric built without a pool (with_pool)"))?;
        Ok(ctl.malloc_mapped(&mut self.cl, tenant, bytes, writable)?)
    }

    /// Free a pool lease and unmap it everywhere.
    pub fn free(&mut self, tenant: TenantId, gva: u64) -> Result<()> {
        let ctl = self
            .controller
            .as_mut()
            .ok_or_else(|| anyhow::anyhow!("fabric built without a pool (with_pool)"))?;
        Ok(ctl.free_mapped(&mut self.cl, tenant, gva)?)
    }

    /// Submit a pooled-memory batch onto the **shared** session — its
    /// packets fly concurrently with every in-flight collective. A
    /// paced client's token bucket rides along as a *plan-private*
    /// pacer (the §2.5 rate-limited READ pull), throttling only this
    /// plan's injections — neighbors flow at full rate. Redeem with
    /// [`wait_mem`](Self::wait_mem).
    pub fn submit_mem(&mut self, batch: MemBatch<'_>) -> Result<MemHandle, MemError> {
        let mut prepared = batch.prepare();
        let idx = self.mem_plans.len();
        if prepared.is_empty() {
            self.mem_plans.push(MemPlanState {
                plan: None,
                prepared: Some(prepared),
            });
            return Ok(MemHandle(idx));
        }
        let record = prepared.wants_responses();
        let window = prepared.window();
        let pace = prepared.pace();
        let wops = prepared.take_ops();
        let plan = match pace {
            Some((gbps, burst)) => self.session.submit_paced(
                &mut self.cl,
                &mut self.eng,
                wops,
                record,
                window,
                TokenBucket::new(gbps, burst),
            ),
            None => self
                .session
                .submit(&mut self.cl, &mut self.eng, wops, record, window),
        }
        .map_err(|e| MemError::Plan(e.to_string()))?;
        self.mem_plans.push(MemPlanState {
            plan: Some(plan),
            prepared: Some(prepared),
        });
        Ok(MemHandle(idx))
    }

    /// Blocking convenience: read `len` bytes at `gva` as one session
    /// plan (batch → submit → wait → redeem).
    pub fn mem_read(
        &mut self,
        client: &MemClient,
        gva: u64,
        len: usize,
    ) -> Result<Vec<u8>, MemError> {
        let mut b = client.batch();
        let h = b.read(&mut self.cl, gva, len);
        let hm = self.submit_mem(b)?;
        let mut res = self.wait_mem(hm)?;
        res.take_read(h).ok_or(MemError::BadResponse { gva })
    }

    /// Blocking convenience: write `data` at `gva` as one session plan.
    pub fn mem_write(
        &mut self,
        client: &MemClient,
        gva: u64,
        data: &[u8],
    ) -> Result<(), MemError> {
        let mut b = client.batch();
        b.write(&mut self.cl, gva, data);
        let hm = self.submit_mem(b)?;
        self.wait_mem(hm)?;
        Ok(())
    }

    /// Drive the shared DES until `h`'s plan settles, then redeem it
    /// (reads reassembled, CAS outcomes, typed NAK errors).
    pub fn wait_mem(&mut self, h: MemHandle) -> Result<BatchResult, MemError> {
        self.drive().map_err(|e| MemError::Plan(e.to_string()))?;
        let st = &mut self.mem_plans[h.0];
        let plan = st.plan;
        let prepared = st
            .prepared
            .take()
            .ok_or_else(|| MemError::Plan("mem handle already redeemed".into()))?;
        match plan {
            None => prepared.redeem(&mut self.cl, 0, None, &[]),
            Some(p) => {
                let out = self.session.outcome(p);
                // Recycle the slab slot; best-effort — an unsettled plan
                // (unrecovered loss, unreliable fabric) stays live.
                if self.session.release(p).is_ok() {
                    self.mem_plans[h.0].plan = None;
                }
                prepared.redeem(&mut self.cl, out.done, out.nak.as_ref(), &out.responses)
            }
        }
    }

    /// Like [`wait_mem`](Self::wait_mem), but also surfaces the plan's
    /// transport stats (per-op latencies, submit/finish times, NAK
    /// cancellation counts) — and surfaces them even when redemption
    /// fails, which is exactly the case the serving aggressor exercises:
    /// a NAK'd plan still carries latencies for the ops that retired
    /// before cancellation, and the serving report needs them.
    pub fn wait_mem_timed(
        &mut self,
        h: MemHandle,
    ) -> (Result<BatchResult, MemError>, MemPlanStats) {
        if let Err(e) = self.drive() {
            return (Err(MemError::Plan(e.to_string())), MemPlanStats::default());
        }
        let st = &mut self.mem_plans[h.0];
        let plan = st.plan;
        let Some(prepared) = st.prepared.take() else {
            return (
                Err(MemError::Plan("mem handle already redeemed".into())),
                MemPlanStats::default(),
            );
        };
        match plan {
            None => (
                prepared.redeem(&mut self.cl, 0, None, &[]),
                MemPlanStats::default(),
            ),
            Some(p) => {
                let out = self.session.outcome(p);
                if self.session.release(p).is_ok() {
                    self.mem_plans[h.0].plan = None;
                }
                let res = prepared.redeem(&mut self.cl, out.done, out.nak.as_ref(), &out.responses);
                let stats = MemPlanStats {
                    ops: out.ops,
                    done: out.done,
                    cancelled: out.cancelled,
                    nakked: out.nak.is_some(),
                    submitted_at: out.submitted_at,
                    last_done: out.last_done,
                    latencies: out.latencies,
                };
                (res, stats)
            }
        }
    }
}

/// Transport-level outcome of one pooled-memory plan, captured alongside
/// redemption by [`Fabric::wait_mem_timed`]. All-integer timing so
/// serving reports built from it stay `Eq`-comparable across DES shard
/// counts.
#[derive(Debug, Clone, Default)]
pub struct MemPlanStats {
    /// Ops the plan submitted.
    pub ops: usize,
    /// Ops retired exactly once.
    pub done: usize,
    /// Queued ops of this plan dropped by its NAK cancellation.
    pub cancelled: usize,
    /// Whether a wire NAK cancelled the plan.
    pub nakked: bool,
    /// Simulated time the plan was submitted.
    pub submitted_at: SimTime,
    /// Time of the plan's last retirement (submit time if none).
    pub last_done: SimTime,
    /// Per-op completion latency (wire release → retirement, ns).
    pub latencies: Vec<SimTime>,
}

// -------------------------------------------------------- communicator

/// A per-tenant handle onto a shared [`Fabric`]: rank identity (ranks
/// 0..N over the fabric's devices), a private memory region, and the
/// nonblocking collective ops. Cheap to hold; all state lives in the
/// fabric.
#[derive(Debug, Clone)]
pub struct Communicator {
    /// Tenant identity (labels; device enforcement keys on source IP).
    pub tenant: TenantId,
    base_addr: u64,
    region_bytes: u64,
    window: usize,
    reliable: bool,
}

impl Communicator {
    /// Device-local base address of this tenant's region.
    pub fn base_addr(&self) -> u64 {
        self.base_addr
    }

    /// Region size in bytes (rounded to the interleave block).
    pub fn region_bytes(&self) -> u64 {
        self.region_bytes
    }

    /// Region capacity in f32 elements.
    pub fn capacity_elems(&self) -> usize {
        (self.region_bytes / 4) as usize
    }

    /// Override the per-slot window for this communicator's ops.
    pub fn with_window(mut self, window: usize) -> Self {
        self.window = window.max(1);
        self
    }

    /// Seed per-rank gradient vectors into this communicator's region.
    /// Panics if `elements` overflows the region — silently scribbling
    /// on a neighbor tenant is the one thing this API must never do.
    pub fn seed_gradients(
        &self,
        f: &mut Fabric,
        elements: usize,
        seed: u64,
    ) -> Vec<Vec<f32>> {
        assert!(
            elements as u64 * 4 <= self.region_bytes,
            "seeding {elements} elements overflows the communicator region"
        );
        crate::collectives::seed_gradients(&mut f.cl, &f.devices, elements, self.base_addr, seed)
    }

    /// Integer-valued seeding — exact under any reduction order (the
    /// oracle for fused-vs-unfused comparisons). Region-bounds checked
    /// like [`seed_gradients`](Self::seed_gradients).
    pub fn seed_gradients_exact(
        &self,
        f: &mut Fabric,
        elements: usize,
        seed: u64,
    ) -> Vec<Vec<f32>> {
        assert!(
            elements as u64 * 4 <= self.region_bytes,
            "seeding {elements} elements overflows the communicator region"
        );
        crate::collectives::seed_gradients_exact(
            &mut f.cl,
            &f.devices,
            elements,
            self.base_addr,
            seed,
        )
    }

    /// Read `elements` f32s of rank `rank`'s region copy back (oracle
    /// checks).
    pub fn read_vector(&self, f: &mut Fabric, rank: usize, elements: usize) -> Result<Vec<f32>> {
        self.read_vector_at(f, rank, 0, elements)
    }

    /// Read an element subrange of rank `rank`'s region copy (e.g. one
    /// tensor span of a bucketed stream).
    pub fn read_vector_at(
        &self,
        f: &mut Fabric,
        rank: usize,
        offset_elems: usize,
        elems: usize,
    ) -> Result<Vec<f32>> {
        ensure!(
            ((offset_elems + elems) as u64) * 4 <= self.region_bytes,
            "read range exceeds the communicator region"
        );
        crate::collectives::read_vector(
            &mut f.cl,
            f.devices[rank],
            self.base_addr + offset_elems as u64 * 4,
            elems,
        )
    }

    /// Stage `data` into rank `rank`'s region copy at `offset_elems` —
    /// host-side gradient staging (a tensor placed at its bucketed
    /// span). No-op on timing-only (phantom) devices.
    pub fn write_vector(
        &self,
        f: &mut Fabric,
        rank: usize,
        offset_elems: usize,
        data: &[f32],
    ) -> Result<()> {
        ensure!(
            ((offset_elems + data.len()) as u64) * 4 <= self.region_bytes,
            "write range exceeds the communicator region"
        );
        let dev = f.devices[rank];
        let d = f.cl.device_mut(dev);
        if d.mem_ref().is_phantom() {
            return Ok(());
        }
        d.mem().write(
            self.base_addr + offset_elems as u64 * 4,
            &crate::util::bytes::f32s_to_bytes(data),
        )?;
        Ok(())
    }

    // -------------------------------------------------- nonblocking ops

    /// Nonblocking allreduce of the leading `elements` of the region
    /// (the §3 fused in-memory ring).
    pub fn iallreduce(&self, f: &mut Fabric, elements: usize) -> Result<CollectiveHandle> {
        self.icollective(f, AlgoKind::NetdamRing, elements, 0)
    }

    /// Nonblocking ring reduce-scatter.
    pub fn ireduce_scatter(&self, f: &mut Fabric, elements: usize) -> Result<CollectiveHandle> {
        self.icollective(f, AlgoKind::ReduceScatter, elements, 0)
    }

    /// Nonblocking ring all-gather.
    pub fn iallgather(&self, f: &mut Fabric, elements: usize) -> Result<CollectiveHandle> {
        self.icollective(f, AlgoKind::AllGather, elements, 0)
    }

    /// Nonblocking broadcast of `root`'s vector.
    pub fn ibcast(&self, f: &mut Fabric, elements: usize, root: usize) -> Result<CollectiveHandle> {
        self.icollective(f, AlgoKind::Broadcast, elements, root)
    }

    /// Nonblocking **rooted reduce**: the whole vector summed at `root`
    /// (every chain ends there; other ranks keep their data).
    pub fn ireduce(&self, f: &mut Fabric, elements: usize, root: usize) -> Result<CollectiveHandle> {
        self.icollective(f, AlgoKind::Reduce, elements, root)
    }

    /// Nonblocking collective by [`AlgoKind`] over the leading
    /// `elements` of the region.
    pub fn icollective(
        &self,
        f: &mut Fabric,
        kind: AlgoKind,
        elements: usize,
        root: usize,
    ) -> Result<CollectiveHandle> {
        self.submit_range(f, kind, 0, elements, root)
    }

    /// Nonblocking allreduce over an element subrange — the primitive
    /// the bucketing layer composes. Ranges of concurrent ops must be
    /// disjoint.
    pub fn iallreduce_range(
        &self,
        f: &mut Fabric,
        offset_elems: usize,
        elems: usize,
    ) -> Result<CollectiveHandle> {
        self.submit_range(f, AlgoKind::NetdamRing, offset_elems, elems, 0)
    }

    /// Lower a pre-planned bucket stream ([`plan_buckets`]): one
    /// nonblocking allreduce per bucket, all in flight together under
    /// the shared session.
    pub fn iallreduce_buckets(
        &self,
        f: &mut Fabric,
        buckets: &[GradBucket],
    ) -> Result<Vec<CollectiveHandle>> {
        let mut handles = Vec::with_capacity(buckets.len());
        for b in buckets {
            handles.push(self.iallreduce_range(f, b.offset_elems, b.elems)?);
        }
        Ok(handles)
    }

    /// Blocking convenience: `iallreduce` + `wait`.
    pub fn allreduce(&self, f: &mut Fabric, elements: usize) -> Result<CollectiveOutcome> {
        let h = self.iallreduce(f, elements)?;
        f.wait(h)
    }

    fn submit_range(
        &self,
        f: &mut Fabric,
        kind: AlgoKind,
        offset_elems: usize,
        elems: usize,
        root: usize,
    ) -> Result<CollectiveHandle> {
        ensure!(
            !kind.is_host_baseline(),
            "{} is a host baseline — it builds its own host fabric",
            kind.name()
        );
        ensure!(elems > 0, "collective of zero elements");
        ensure!(
            ((offset_elems + elems) as u64) * 4 <= self.region_bytes,
            "collective range [{offset_elems}..+{elems}) exceeds the communicator region"
        );
        let algo = kind.planner(f.devices.len(), &f.topo, root)?;
        let spec = CollectiveSpec {
            elements: elems,
            window: self.window,
            reliable: self.reliable,
            base_addr: self.base_addr + offset_elems as u64 * 4,
            tenant: self.tenant,
            ..CollectiveSpec::default()
        };
        f.submit_algo(algo, spec)
    }
}

// ----------------------------------------------------------- bucketing

/// One tensor's placement inside the packed gradient stream.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TensorSpan {
    /// Index into the caller's tensor list.
    pub tensor: usize,
    /// Element offset within the communicator region.
    pub offset_elems: usize,
    pub elems: usize,
}

/// A fused bucket: a contiguous region slice carrying several packed
/// tensors, allreduced as one collective.
#[derive(Debug, Clone)]
pub struct GradBucket {
    pub offset_elems: usize,
    /// Slice length, padded to a rank multiple (the ring chunking
    /// requirement); padding tail elements are reduced too, harmlessly.
    pub elems: usize,
    pub tensors: Vec<TensorSpan>,
}

/// Pack a stream of small tensors into buckets of at most `bucket_elems`
/// elements (the fusion-buffer trick: tiny gradients stop paying one
/// full collective schedule each). `bucket_elems == 0` means *no
/// fusion* — every tensor gets its own bucket (the unfused baseline the
/// bench compares against). Buckets are padded to a multiple of
/// `ranks`; an oversized tensor gets a bucket of its own.
pub fn plan_buckets(sizes: &[usize], bucket_elems: usize, ranks: usize) -> Vec<GradBucket> {
    let ranks = ranks.max(1);
    let cap = bucket_elems.max(1);
    let mut buckets = Vec::new();
    let mut cursor = 0usize;
    let mut i = 0usize;
    while i < sizes.len() {
        let start = cursor;
        let mut tensors = Vec::new();
        let mut fill = 0usize;
        while i < sizes.len() {
            let s = sizes[i].max(1);
            if !tensors.is_empty() && fill + s > cap {
                break;
            }
            tensors.push(TensorSpan {
                tensor: i,
                offset_elems: start + fill,
                elems: s,
            });
            fill += s;
            i += 1;
            if fill >= cap {
                break;
            }
        }
        let padded = fill.div_ceil(ranks) * ranks;
        buckets.push(GradBucket {
            offset_elems: start,
            elems: padded,
            tensors,
        });
        cursor = start + padded;
    }
    buckets
}

/// Total packed elements (region footprint) of a bucket plan.
pub fn buckets_total_elems(buckets: &[GradBucket]) -> usize {
    buckets
        .last()
        .map(|b| b.offset_elems + b.elems)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_plan_packs_and_pads() {
        // 6 tensors, cap 100, 4 ranks.
        let sizes = [40usize, 50, 30, 120, 10, 10];
        let b = plan_buckets(&sizes, 100, 4);
        // [40+50]=90→92, [30]… 30+120>100 → [30]→32, [120]→120, [10+10]→20.
        assert_eq!(b.len(), 4);
        assert_eq!(b[0].tensors.len(), 2);
        assert_eq!(b[0].elems, 92);
        assert_eq!(b[1].tensors.len(), 1);
        assert_eq!(b[1].elems, 32);
        assert_eq!(b[2].tensors[0].tensor, 3);
        assert_eq!(b[2].elems, 120);
        assert_eq!(b[3].tensors.len(), 2);
        // Spans are disjoint and in order.
        for w in b.windows(2) {
            assert!(w[1].offset_elems >= w[0].offset_elems + w[0].elems);
        }
        for bk in &b {
            for t in &bk.tensors {
                assert!(t.offset_elems >= bk.offset_elems);
                assert!(t.offset_elems + t.elems <= bk.offset_elems + bk.elems);
            }
            assert_eq!(bk.elems % 4, 0, "padded to a rank multiple");
        }
        assert_eq!(buckets_total_elems(&b), b[3].offset_elems + b[3].elems);
    }

    #[test]
    fn zero_bucket_elems_means_unfused() {
        let sizes = [7usize, 9, 3];
        let b = plan_buckets(&sizes, 0, 4);
        assert_eq!(b.len(), 3, "every tensor gets its own bucket");
        for (i, bk) in b.iter().enumerate() {
            assert_eq!(bk.tensors.len(), 1);
            assert_eq!(bk.tensors[0].tensor, i);
            assert_eq!(bk.elems % 4, 0);
        }
    }

    #[test]
    fn fabric_builds_once_and_runs_a_blocking_allreduce() {
        let mut f = Fabric::builder().star(4).seed(0xC0).build().unwrap();
        let comm = f.communicator(64 << 10).unwrap();
        let elements = 4 * 2048;
        let grads = comm.seed_gradients(&mut f, elements, 7);
        let out = comm.allreduce(&mut f, elements).unwrap();
        assert!(out.complete(), "{}/{} ops", out.ops_done, out.ops);
        assert!(out.elapsed_ns() > 0);
        let oracle = crate::collectives::oracle_sum(&grads);
        for r in 0..4 {
            assert_eq!(comm.read_vector(&mut f, r, elements).unwrap(), oracle);
        }
    }

    #[test]
    fn two_communicators_use_disjoint_regions() {
        let mut f = Fabric::builder().star(4).build().unwrap();
        let a = f.communicator(16 << 10).unwrap();
        let b = f.communicator(16 << 10).unwrap();
        assert!(a.base_addr() + a.region_bytes() <= b.base_addr());
        assert_ne!(a.tenant, b.tenant);
    }

    #[test]
    fn multi_phase_hierarchical_runs_on_the_session() {
        let mut f = Fabric::builder()
            .fat_tree(2, 2, 2)
            .seed(0x2E)
            .build()
            .unwrap();
        let comm = f.communicator(64 << 10).unwrap();
        let elements = 4 * 2048;
        let grads = comm.seed_gradients_exact(&mut f, elements, 9);
        let h = comm
            .icollective(&mut f, AlgoKind::Hierarchical, elements, 0)
            .unwrap();
        let out = f.wait(h).unwrap();
        assert!(out.complete());
        let oracle = crate::collectives::naive_sum(&grads);
        for r in 0..4 {
            assert_eq!(comm.read_vector(&mut f, r, elements).unwrap(), oracle);
        }
    }
}
