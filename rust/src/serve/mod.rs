//! The serving tier: a disaggregated multi-tenant KV/embedding workload
//! driving the pooled fabric the way a production inference tier would.
//!
//! A [`ServeConfig`] describes a fleet of tenants, each with a private
//! seeded request stream ([`workload::TenantWorkload`]): Zipf-skewed
//! keys over leases in the block-interleaved GVA pool, mixed
//! GET/PUT/CAS plus TensorDIMM-style embedding bags lowered onto
//! near-memory `gather_sum` programs. [`run`] executes the fleet on ONE
//! [`crate::comm::Fabric`] — every tenant's wave plan is submitted
//! before any is redeemed, so plans genuinely contend on the shared
//! session, the devices, and the switch ports — while scratch leases
//! churn (`free` + `malloc` under live neighbor traffic) and, when
//! enabled, a deliberately misbehaving **aggressor** runs alongside:
//!
//! * a **NAK storm** — its plans are compiled against a lease the
//!   controller already revoked, so every access dies as a typed wire
//!   NAK and per-plan cancellation (never touching a neighbor's plan);
//! * an **incast burst** — bulk reads whose responses converge on the
//!   aggressor's host port, pressuring the shared device egress links
//!   (and, under [`CcMode::Dcqcn`], getting rate-controlled for it).
//!
//! The subsystem owns its reporting: per-tenant p50/p99/p99.9 latency
//! ([`crate::util::stats::TailNs`] — all-integer, so reports are
//! bit-comparable across DES shard counts), goodput, NAK/cancellation
//! counts, plus fabric-wide retransmit/CNP/churn counters
//! ([`ServeReport`]). [`isolation_check`] turns that into a verdict:
//! the same seeded fleet runs with and without the aggressor on an
//! identical topology, and every well-behaved tenant's p99 must stay
//! within a configured bound of its aggressor-free baseline.
//!
//! Surfaces: `netdam serve` (CLI), `coordinator::run_e5` (experiment
//! arm), `cargo bench --bench serving` (`BENCH_serving.json` grid), and
//! `rust/tests/serving_isolation.rs` (the isolation + cross-shard
//! determinism contract).

pub mod workload;

mod runner;

use anyhow::{ensure, Result};

use crate::isa::MAX_PROGRAM_STEPS;
use crate::transport::CcMode;

pub use runner::{run, ServeReport, TenantReport};
pub use workload::{Mix, Request, TenantWorkload};

/// The pool interleave block (and lease granule) — serving layouts are
/// sized so no value, CAS word, or gather row ever straddles one.
pub const BLOCK: u64 = 8192;

/// Packets per storm plan the aggressor throws at its revoked lease
/// each wave (all die as typed NAKs; the tail is cancelled).
pub const STORM_OPS: usize = 8;

/// Full description of one serving run. Every field is data — two runs
/// with equal configs produce bit-identical [`ServeReport`] integer
/// fields at any DES shard count.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Well-behaved tenants (each gets its own host and pool tenant id).
    pub tenants: usize,
    /// Devices in the star fabric (the pool interleaves across all).
    pub devices: usize,
    /// Keys per tenant; key `k` lives at `data_gva + k * value_bytes`.
    pub keys_per_tenant: u64,
    /// Value size. Must be ≥ 8 and divide [`BLOCK`] so values, CAS
    /// words, and gather rows stay within one interleave block.
    pub value_bytes: usize,
    /// Scheduling rounds; each wave submits every tenant's plan before
    /// redeeming any (open-loop contention).
    pub waves: usize,
    /// Logical requests per tenant per wave.
    pub ops_per_wave: usize,
    /// Rows per embedding bag (bounded by the packet-program budget).
    pub gather_bag: usize,
    /// Zipf skew θ (0.0 = uniform; ~0.99 = classic serving-cache skew).
    pub skew: f64,
    /// GET/PUT/CAS/GATHER weights.
    pub mix: Mix,
    /// Per-tenant per-wave probability of scratch-lease churn
    /// (free + malloc re-programming every device IOMMU under live
    /// neighbor traffic).
    pub churn: f64,
    /// Run the misbehaving tenant alongside the fleet.
    pub aggressor: bool,
    /// Bytes the aggressor's incast burst pulls per wave.
    pub burst_bytes: usize,
    pub seed: u64,
    /// DES shards (0 = classic single-heap engine).
    pub shards: usize,
    /// Shard worker threads (0 = auto; tests pin 1).
    pub shard_threads: usize,
    pub cc: CcMode,
    /// RED ECN ramp override for every link (`None` keeps the
    /// `dc_100g` default of 100–300 KB, which small serving runs never
    /// reach; the serving default forces marks early so DCQCN engages).
    pub ecn: Option<(usize, usize)>,
    /// Pool capacity contributed per device (multiple of [`BLOCK`]).
    pub pool_per_device: u64,
    /// Per-device in-flight window per plan.
    pub window: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            tenants: 4,
            devices: 4,
            keys_per_tenant: 256,
            value_bytes: 512,
            waves: 4,
            ops_per_wave: 24,
            gather_bag: 4,
            skew: 0.99,
            mix: Mix::serving_default(),
            churn: 0.25,
            aggressor: false,
            burst_bytes: 64 << 10,
            seed: 0x5E11E,
            shards: 1,
            shard_threads: 1,
            cc: CcMode::Static,
            ecn: Some((2_000, 20_000)),
            pool_per_device: 4 << 20,
            window: 4,
        }
    }
}

impl ServeConfig {
    /// Shape checks, including that the whole fleet's leases fit the
    /// pool. Called by [`run`]; errors carry the violated constraint.
    pub fn validate(&self) -> Result<()> {
        ensure!(self.tenants >= 1, "need at least one tenant");
        ensure!(self.devices >= 1, "need at least one device");
        ensure!(self.keys_per_tenant >= 1, "need at least one key per tenant");
        ensure!(self.waves >= 1 && self.ops_per_wave >= 1, "need a non-empty schedule");
        ensure!(
            self.value_bytes >= 8 && BLOCK % self.value_bytes as u64 == 0,
            "value_bytes must be >= 8 and divide the {BLOCK} B interleave block \
             (got {})",
            self.value_bytes
        );
        ensure!(
            (1..MAX_PROGRAM_STEPS).contains(&self.gather_bag),
            "gather_bag must be 1..={} (packet-program step budget)",
            MAX_PROGRAM_STEPS - 1
        );
        ensure!(
            self.skew.is_finite() && self.skew >= 0.0,
            "skew must be a finite non-negative Zipf theta"
        );
        ensure!((0.0..=1.0).contains(&self.churn), "churn must be a probability");
        ensure!(self.mix.total() > 0, "request mix must have a positive weight");
        ensure!(
            self.pool_per_device >= BLOCK && self.pool_per_device % BLOCK == 0,
            "pool_per_device must be a positive multiple of {BLOCK}"
        );
        ensure!(self.window >= 1, "window must be >= 1");
        let round = |b: u64| b.div_ceil(BLOCK) * BLOCK;
        // data + gather dst + scratch per tenant; the aggressor adds a
        // revoked granule plus its burst lease.
        let per_tenant = round(self.keys_per_tenant * self.value_bytes as u64) + 2 * BLOCK;
        let aggressor = BLOCK + round(self.burst_bytes.max(1) as u64);
        let need = self.tenants as u64 * per_tenant + aggressor;
        let capacity = self.pool_per_device * self.devices as u64;
        ensure!(
            need <= capacity,
            "fleet needs {need} B of pool but capacity is {capacity} B \
             ({} B/device x {} devices)",
            self.pool_per_device,
            self.devices
        );
        Ok(())
    }
}

/// The outcome of an aggressor A/B: the same seeded fleet with and
/// without the misbehaving tenant, on an identical topology.
#[derive(Debug, Clone)]
pub struct IsolationVerdict {
    pub baseline: ServeReport,
    pub contended: ServeReport,
    /// `max_i 1000 * p99_contended(i) / p99_baseline(i)` over the
    /// well-behaved tenants (integer thousandths, so verdicts stay
    /// bit-comparable across shard counts).
    pub worst_ratio_milli: u64,
    /// The bound the verdict was judged against.
    pub bound_milli: u64,
    /// True when every well-behaved tenant's p99 stayed within the
    /// bound *and* completed its work NAK-free.
    pub ok: bool,
}

/// Run the isolation A/B: `cfg` with `aggressor` forced off, then on,
/// same seed and topology (the aggressor's host exists but stays idle
/// in the baseline, so only the traffic differs). `bound_milli` is the
/// allowed p99 inflation in thousandths — `2000` = "p99 may at most
/// double".
pub fn isolation_check(cfg: &ServeConfig, bound_milli: u64) -> Result<IsolationVerdict> {
    let mut base = cfg.clone();
    base.aggressor = false;
    let mut contested = cfg.clone();
    contested.aggressor = true;
    let baseline = run(&base)?;
    let contended = run(&contested)?;
    ensure!(
        baseline.tenants.len() == contended.tenants.len(),
        "A/B arms disagree on tenant count"
    );
    let mut worst = 0u64;
    let mut clean = true;
    for (b, c) in baseline.tenants.iter().zip(&contended.tenants) {
        ensure!(b.tenant == c.tenant, "A/B arms disagree on tenant order");
        let ratio = c.tail.p99 * 1000 / b.tail.p99.max(1);
        worst = worst.max(ratio);
        clean &= c.naks == 0 && c.done == c.ops;
    }
    Ok(IsolationVerdict {
        ok: clean && worst <= bound_milli,
        baseline,
        contended,
        worst_ratio_milli: worst,
        bound_milli,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validate_catches_bad_shapes() {
        let ok = ServeConfig::default();
        ok.validate().unwrap();
        let mut bad = ok.clone();
        bad.value_bytes = 600; // does not divide 8192
        assert!(bad.validate().is_err());
        let mut bad = ok.clone();
        bad.gather_bag = MAX_PROGRAM_STEPS; // one over the step budget
        assert!(bad.validate().is_err());
        let mut bad = ok.clone();
        bad.pool_per_device = BLOCK; // 4 devices x 8 KiB cannot hold the fleet
        assert!(bad.validate().is_err());
        let mut bad = ok;
        bad.churn = 1.5;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn isolation_verdict_on_a_tiny_fleet() {
        let cfg = ServeConfig {
            tenants: 3,
            keys_per_tenant: 64,
            waves: 2,
            ops_per_wave: 12,
            seed: 0x15_0A7E,
            ..Default::default()
        };
        // A generous bound: this test pins the A/B *mechanics* (the
        // 2x-bound contract lives in rust/tests/serving_isolation.rs).
        let v = isolation_check(&cfg, 10_000).unwrap();
        assert!(v.ok, "worst ratio {} exceeded 10x", v.worst_ratio_milli);
        assert!(v.worst_ratio_milli >= 1, "ratio should be a positive milli value");
        assert!(v.baseline.aggressor.is_none());
        let agg = v.contended.aggressor.as_ref().expect("aggressor report");
        assert!(agg.naks > 0, "the storm never NAK'd");
        // The baseline fleet never even sees a NAK.
        assert!(v.baseline.tenants.iter().all(|t| t.naks == 0));
    }
}
