//! The serving fleet runner: builds ONE pooled fabric, leases every
//! tenant's key space, and drives the wave schedule — submit every
//! tenant's plan, churn scratch leases under the live traffic, then
//! redeem — while the optional aggressor storms its revoked lease and
//! pulls incast bursts alongside. See the module docs in
//! [`super`](crate::serve) for the full picture.

use anyhow::{anyhow, bail, ensure, Result};

use super::workload::{stream_seed, Request, TenantWorkload};
use super::{ServeConfig, BLOCK, STORM_OPS};
use crate::comm::{Fabric, MemHandle, MemPlanStats};
use crate::mem::{MemClient, MemError};
use crate::metrics::Table;
use crate::net::LinkConfig;
use crate::pool::{Allocation, TenantId};
use crate::sim::fmt_ns;
use crate::util::stats::{tail_ns, TailNs};
use crate::util::Xoshiro256;

/// One tenant's scoreboard (well-behaved or aggressor).
#[derive(Debug, Clone)]
pub struct TenantReport {
    pub tenant: TenantId,
    /// Logical serving requests issued (GET/PUT/CAS/GATHER count as one
    /// each; the aggressor's storm + burst plans count per packet-op).
    pub requests: usize,
    /// Transport ops submitted (one windowed packet-op can back several
    /// interleave pieces of one logical request).
    pub ops: usize,
    /// Transport ops retired exactly once.
    pub done: usize,
    /// Plans killed by a typed wire NAK.
    pub naks: usize,
    /// Queued ops dropped by per-plan NAK cancellation.
    pub cancelled: usize,
    /// Payload bytes the tenant's requests moved (planned).
    pub bytes: u64,
    /// Whole-run latency tail (per retired transport op, ns).
    pub tail: TailNs,
    /// `bytes * 8 / elapsed_ns` — Gbit/s over the whole run.
    pub goodput_gbps: f64,
}

/// The whole run's report. All integer fields (everything except
/// `goodput_gbps`) are bit-identical across DES shard counts —
/// [`Self::fingerprint`] is the comparison key the determinism tests
/// use.
#[derive(Debug, Clone)]
pub struct ServeReport {
    /// Well-behaved tenants, in tenant order.
    pub tenants: Vec<TenantReport>,
    /// The misbehaving tenant, when the config enabled it.
    pub aggressor: Option<TenantReport>,
    pub elapsed_ns: u64,
    /// Fabric-cumulative timeout retransmits.
    pub retransmits: u64,
    /// CE-marked completions absorbed as CNPs (DCQCN runs only).
    pub cnps: usize,
    /// Scratch leases recycled (free + malloc) under live traffic.
    pub churn_events: usize,
    /// High-water mark of concurrently live session plans.
    pub max_concurrent_plans: usize,
}

/// One fingerprint row: tenant id, requests, done, naks, cancelled,
/// bytes, latency tail.
pub type FingerprintRow = (TenantId, usize, usize, usize, usize, u64, TailNs);

impl ServeReport {
    /// Integer-only comparison key (per-tenant rows with the aggressor
    /// appended, plus the global counters): equal configs must produce
    /// equal fingerprints at any shard count.
    pub fn fingerprint(&self) -> (Vec<FingerprintRow>, u64, u64, usize) {
        let rows = self
            .tenants
            .iter()
            .chain(self.aggressor.iter())
            .map(|t| (t.tenant, t.requests, t.done, t.naks, t.cancelled, t.bytes, t.tail))
            .collect();
        (rows, self.elapsed_ns, self.retransmits, self.cnps)
    }

    /// Worst well-behaved p99 (the isolation bound's left-hand side).
    pub fn worst_p99(&self) -> u64 {
        self.tenants.iter().map(|t| t.tail.p99).max().unwrap_or(0)
    }

    /// Human-readable per-tenant table plus the fabric-wide footer.
    pub fn render(&self) -> String {
        let mut table = Table::new(&[
            "tenant", "requests", "done/ops", "p50", "p99", "p99.9", "goodput", "naks",
            "cancelled",
        ]);
        for t in self.tenants.iter().chain(self.aggressor.iter()) {
            let label = if self.aggressor.as_ref().is_some_and(|a| a.tenant == t.tenant) {
                format!("{} (aggressor)", t.tenant)
            } else {
                t.tenant.to_string()
            };
            table.row(&[
                label,
                t.requests.to_string(),
                format!("{}/{}", t.done, t.ops),
                fmt_ns(t.tail.p50),
                fmt_ns(t.tail.p99),
                fmt_ns(t.tail.p999),
                format!("{:.2} Gbps", t.goodput_gbps),
                t.naks.to_string(),
                t.cancelled.to_string(),
            ]);
        }
        format!(
            "{}\nelapsed {} | retx {} | cnps {} | churn {} | {} plans live at peak\n",
            table.render(),
            fmt_ns(self.elapsed_ns),
            self.retransmits,
            self.cnps,
            self.churn_events,
            self.max_concurrent_plans
        )
    }
}

/// Host-side state of one well-behaved tenant.
struct TenantState {
    client: MemClient,
    wl: TenantWorkload,
    /// Base GVA of the key region; key `k` is at `data + k * value`.
    data: u64,
    /// One-block lease gather bags fold into.
    gather_dst: u64,
    /// The churn victim: recycled (free + malloc) between waves.
    scratch: Allocation,
    /// The PUT payload (per-tenant pattern, written repeatedly).
    payload: Vec<u8>,
    requests: usize,
    ops: usize,
    done: usize,
    naks: usize,
    cancelled: usize,
    bytes: u64,
    latencies: Vec<u64>,
    churn_events: usize,
}

impl TenantState {
    fn key_gva(&self, key: u64, value_bytes: usize) -> u64 {
        self.data + key * value_bytes as u64
    }

    fn absorb(&mut self, stats: &MemPlanStats) {
        self.ops += stats.ops;
        self.done += stats.done;
        self.cancelled += stats.cancelled;
        if stats.nakked {
            self.naks += 1;
        }
        self.latencies.extend_from_slice(&stats.latencies);
    }

    fn report(&self, tenant: TenantId, elapsed_ns: u64) -> TenantReport {
        TenantReport {
            tenant,
            requests: self.requests,
            ops: self.ops,
            done: self.done,
            naks: self.naks,
            cancelled: self.cancelled,
            bytes: self.bytes,
            tail: tail_ns(&self.latencies),
            goodput_gbps: self.bytes as f64 * 8.0 / elapsed_ns.max(1) as f64,
        }
    }
}

/// The misbehaving tenant: a lease the controller already revoked (its
/// plans compile fine against the client's stale map and die as typed
/// wire NAKs) plus a valid lease it pulls incast bursts from.
struct AggressorState {
    revoked_gva: u64,
    burst: Allocation,
    state: TenantState,
}

/// Execute the serving schedule. See [`ServeConfig`] for the knobs.
pub fn run(cfg: &ServeConfig) -> Result<ServeReport> {
    cfg.validate()?;
    ensure!(cfg.tenants <= 4096, "tenant fleet capped at 4096");

    // One host per tenant plus one for the aggressor — reserved even in
    // baseline runs so the A/B compares identical topologies.
    let mut link = LinkConfig::dc_100g();
    if let Some((lo, hi)) = cfg.ecn {
        link = link.with_ecn(lo, hi);
    }
    let mut builder = Fabric::builder()
        .star(cfg.devices)
        .hosts(cfg.tenants + 1)
        .seed(cfg.seed)
        .window(cfg.window)
        .link(link)
        .with_pool(cfg.pool_per_device)
        .with_congestion_control(cfg.cc.clone());
    if cfg.shards > 0 {
        builder = builder.with_shards(cfg.shards).shard_threads(cfg.shard_threads);
    }
    let mut fabric = builder.build()?;

    // Lease the fleet: per tenant a key region, a gather-dst block, and
    // the scratch block that churns. Leases are granule-aligned and
    // value_bytes divides the block, so no value/CAS word/gather row
    // ever straddles an interleave block.
    let mut tenants: Vec<TenantState> = Vec::with_capacity(cfg.tenants);
    for i in 0..cfg.tenants {
        let client = fabric.mem_client()?;
        let tenant = client.tenant;
        let data = fabric.malloc(tenant, cfg.keys_per_tenant * cfg.value_bytes as u64, true)?;
        let dst = fabric.malloc(tenant, BLOCK, true)?;
        let scratch = fabric.malloc(tenant, BLOCK, true)?;
        let payload: Vec<u8> = (0..cfg.value_bytes)
            .map(|b| (b as u8).wrapping_mul(31).wrapping_add(i as u8))
            .collect();
        tenants.push(TenantState {
            wl: TenantWorkload::new(
                cfg.seed,
                i,
                cfg.keys_per_tenant,
                cfg.skew,
                cfg.mix,
                cfg.gather_bag,
            ),
            client,
            data: data.gva,
            gather_dst: dst.gva,
            scratch,
            payload,
            requests: 0,
            ops: 0,
            done: 0,
            naks: 0,
            cancelled: 0,
            bytes: 0,
            latencies: Vec::new(),
            churn_events: 0,
        });
    }

    let mut aggressor = if cfg.aggressor {
        let client = fabric.mem_client()?;
        let tenant = client.tenant;
        // The revoked lease: mapped, then immediately freed — the
        // client's map clone still compiles plans against it, so every
        // storm op is enforced (and NAK'd) by the device IOMMUs.
        let revoked = fabric.malloc(tenant, BLOCK, true)?;
        fabric.free(tenant, revoked.gva)?;
        let burst = fabric.malloc(tenant, cfg.burst_bytes.max(1) as u64, true)?;
        Some(AggressorState {
            revoked_gva: revoked.gva,
            burst,
            state: TenantState {
                wl: TenantWorkload::new(
                    cfg.seed,
                    cfg.tenants,
                    cfg.keys_per_tenant,
                    cfg.skew,
                    cfg.mix,
                    cfg.gather_bag,
                ),
                data: 0,
                gather_dst: 0,
                scratch: Allocation {
                    gva: 0,
                    len: 0,
                    tenant,
                    writable: false,
                },
                payload: Vec::new(),
                requests: 0,
                ops: 0,
                done: 0,
                naks: 0,
                cancelled: 0,
                bytes: 0,
                latencies: Vec::new(),
                churn_events: 0,
                client,
            },
        })
    } else {
        None
    };

    // Control-plane stream (churn coin flips) — decorrelated from every
    // tenant's request stream.
    let mut ctl_rng = Xoshiro256::seed_from(stream_seed(cfg.seed, 0xC0DE));
    let t0 = fabric.now();

    for wave in 0..cfg.waves {
        // 1. Submit every tenant's wave plan before redeeming any: the
        //    open-loop moment where plans contend on the shared session,
        //    the devices, and the switch ports.
        let mut handles: Vec<(usize, MemHandle)> = Vec::with_capacity(cfg.tenants);
        for (i, t) in tenants.iter_mut().enumerate() {
            let mut b = t.client.batch();
            for _ in 0..cfg.ops_per_wave {
                t.requests += 1;
                match t.wl.next_request() {
                    Request::Get(k) => {
                        let gva = t.key_gva(k, cfg.value_bytes);
                        b.read(fabric.cluster_mut(), gva, cfg.value_bytes);
                        t.bytes += cfg.value_bytes as u64;
                    }
                    Request::Put(k) => {
                        let gva = t.key_gva(k, cfg.value_bytes);
                        b.write(fabric.cluster_mut(), gva, &t.payload);
                        t.bytes += cfg.value_bytes as u64;
                    }
                    Request::Cas(k) => {
                        // Optimistic bump: losing the compare is a valid
                        // serving outcome, not an error.
                        let gva = t.key_gva(k, cfg.value_bytes);
                        b.cas(fabric.cluster_mut(), gva, 0, wave as u64 + 1)?;
                        t.bytes += 8;
                    }
                    Request::Gather(rows) => {
                        let gvas: Vec<u64> =
                            rows.iter().map(|&k| t.key_gva(k, cfg.value_bytes)).collect();
                        b.gather_sum(fabric.cluster_mut(), &gvas, cfg.value_bytes, t.gather_dst)?;
                        t.bytes += cfg.value_bytes as u64;
                    }
                }
            }
            let h = fabric.submit_mem(b).map_err(|e| anyhow!("tenant {i} submit: {e}"))?;
            handles.push((i, h));
        }

        // 2. The aggressor's two plans ride the same session: the NAK
        //    storm against its revoked lease, and the incast burst whose
        //    responses converge on its one host port.
        let mut agg_handles: Vec<(bool, MemHandle)> = Vec::new();
        if let Some(a) = aggressor.as_mut() {
            let mut storm = a.state.client.batch();
            for _ in 0..STORM_OPS {
                storm.read(fabric.cluster_mut(), a.revoked_gva, cfg.value_bytes);
                a.state.requests += 1;
            }
            agg_handles.push((true, fabric.submit_mem(storm).map_err(|e| anyhow!("storm: {e}"))?));
            let mut burst = a.state.client.batch();
            let mut off = 0u64;
            while off < a.burst.len {
                let chunk = (a.burst.len - off).min(BLOCK) as usize;
                burst.read(fabric.cluster_mut(), a.burst.gva + off, chunk);
                a.state.requests += 1;
                a.state.bytes += chunk as u64;
                off += chunk as u64;
            }
            agg_handles.push((false, fabric.submit_mem(burst).map_err(|e| anyhow!("burst: {e}"))?));
        }

        // 3. Lease churn UNDER the live traffic: free + malloc reprogram
        //    every device IOMMU while neighbors' plans are in flight.
        //    Well-behaved streams never touch scratch, so churn exercises
        //    the control plane concurrency without self-NAKs (the freed-
        //    lease-with-inflight-ops case is the aggressor's storm and
        //    the pool_props property test).
        for t in tenants.iter_mut() {
            if ctl_rng.chance(cfg.churn) {
                let tenant = t.client.tenant;
                fabric.free(tenant, t.scratch.gva)?;
                t.scratch = fabric.malloc(tenant, BLOCK, true)?;
                t.churn_events += 1;
            }
        }

        // 4. Redeem. The first wait drives the shared DES to quiescence,
        //    so every plan of the wave completes under full contention.
        for (i, h) in handles.drain(..) {
            let (res, stats) = fabric.wait_mem_timed(h);
            tenants[i].absorb(&stats);
            res.map_err(|e| anyhow!("tenant {i} wave {wave}: {e}"))?;
        }
        if let Some(a) = aggressor.as_mut() {
            for (is_storm, h) in agg_handles.drain(..) {
                let (res, stats) = fabric.wait_mem_timed(h);
                a.state.absorb(&stats);
                match res {
                    Err(MemError::Nak { .. }) if is_storm => {} // the storm's designed fate
                    Ok(_) if !is_storm => {}
                    Ok(_) => bail!("storm plan against a revoked lease completed"),
                    Err(e) => bail!("aggressor wave {wave}: {e}"),
                }
            }
        }
    }

    let elapsed_ns = fabric.now() - t0;
    let tenant_reports = tenants
        .iter()
        .map(|t| t.report(t.client.tenant, elapsed_ns))
        .collect();
    Ok(ServeReport {
        tenants: tenant_reports,
        aggressor: aggressor
            .as_ref()
            .map(|a| a.state.report(a.state.client.tenant, elapsed_ns)),
        elapsed_ns,
        retransmits: fabric.cluster().xport.retransmits,
        cnps: fabric.cnps(),
        churn_events: tenants.iter().map(|t| t.churn_events).sum(),
        max_concurrent_plans: fabric.max_concurrent_plans(),
    })
}

#[cfg(test)]
mod tests {
    use super::super::ServeConfig;
    use super::*;

    fn tiny() -> ServeConfig {
        ServeConfig {
            tenants: 3,
            keys_per_tenant: 64,
            waves: 2,
            ops_per_wave: 16,
            seed: 0x7E57,
            ..Default::default()
        }
    }

    #[test]
    fn fleet_completes_cleanly_and_deterministically() {
        let r1 = run(&tiny()).unwrap();
        let r2 = run(&tiny()).unwrap();
        assert_eq!(r1.fingerprint(), r2.fingerprint(), "same config, same report");
        assert_eq!(r1.tenants.len(), 3);
        for t in &r1.tenants {
            assert_eq!(t.requests, 2 * 16);
            assert_eq!(t.done, t.ops, "tenant {} stranded ops", t.tenant);
            assert_eq!(t.naks, 0);
            assert_eq!(t.cancelled, 0);
            assert!(t.tail.count > 0 && t.tail.p50 > 0);
            assert!(t.bytes > 0 && t.goodput_gbps > 0.0);
        }
        // Open-loop really happened: all wave plans were live at once.
        assert!(r1.max_concurrent_plans >= 3, "plans never overlapped");
        assert!(r1.elapsed_ns > 0);
    }

    #[test]
    fn aggressor_is_cancelled_not_the_neighbors() {
        let cfg = ServeConfig {
            aggressor: true,
            ..tiny()
        };
        let r = run(&cfg).unwrap();
        let agg = r.aggressor.as_ref().expect("aggressor report");
        // One storm plan per wave dies as a typed NAK; its queued tail
        // is cancelled rather than retried.
        assert_eq!(agg.naks, cfg.waves, "every storm plan must NAK");
        assert!(agg.cancelled > 0, "NAK cancellation never dropped queued ops");
        // The burst plans completed — the aggressor moved real bytes too.
        assert!(agg.done > 0 && agg.bytes > 0);
        // Neighbors: correctness untouched (the latency bound is the
        // integration test's job).
        for t in &r.tenants {
            assert_eq!(t.naks, 0, "tenant {} caught a foreign NAK", t.tenant);
            assert_eq!(t.done, t.ops, "tenant {} lost ops to the aggressor", t.tenant);
        }
    }

    #[test]
    fn churn_reprograms_every_wave_under_live_traffic() {
        let cfg = ServeConfig {
            churn: 1.0,
            ..tiny()
        };
        let r = run(&cfg).unwrap();
        assert_eq!(r.churn_events, 3 * 2, "every tenant churns every wave");
        for t in &r.tenants {
            assert_eq!(t.done, t.ops);
            assert_eq!(t.naks, 0, "churned scratch must never NAK live plans");
        }
    }

    #[test]
    fn classic_and_dcqcn_arms_run() {
        let classic = ServeConfig {
            shards: 0,
            ..tiny()
        };
        let r = run(&classic).unwrap();
        assert!(r.tenants.iter().all(|t| t.done == t.ops));

        let dcqcn = ServeConfig {
            cc: crate::transport::CcMode::Dcqcn(crate::roce::DcqcnConfig::default()),
            ..tiny()
        };
        let r = run(&dcqcn).unwrap();
        assert!(r.tenants.iter().all(|t| t.done == t.ops && t.naks == 0));
    }
}
