//! Deterministic multi-tenant request generation for the serving tier.
//!
//! Every tenant owns a private, seeded request stream: Zipf-distributed
//! keys (the classic serving-cache skew — see [`crate::util::Zipf`])
//! drawn through a weighted GET/PUT/CAS/GATHER mix, with GATHER emitting
//! TensorDIMM-style embedding bags (several rows folded by one
//! near-memory `gather_sum` program). Streams are derived from the run
//! seed with [`stream_seed`], so adding or removing a tenant never
//! perturbs the sequences of the others — the property the isolation
//! A/B leans on when it replays the same tenants with and without an
//! aggressor.

use crate::util::{SplitMix64, Xoshiro256, Zipf};

/// Request-mix weights (parts, not percentages — any positive total
/// works). The serving default leans read-heavy like a production
/// KV/embedding tier: 60/25/10/5 GET/PUT/CAS/GATHER.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Mix {
    pub get: u32,
    pub put: u32,
    pub cas: u32,
    pub gather: u32,
}

impl Mix {
    /// The read-heavy serving default: 60/25/10/5.
    pub const fn serving_default() -> Self {
        Self {
            get: 60,
            put: 25,
            cas: 10,
            gather: 5,
        }
    }

    /// Parse `"get/put/cas/gather"` weights, e.g. `"60/25/10/5"`.
    /// Returns `None` on malformed input or an all-zero mix.
    pub fn parse(s: &str) -> Option<Self> {
        let parts: Vec<&str> = s.split('/').collect();
        if parts.len() != 4 {
            return None;
        }
        let mut w = [0u32; 4];
        for (slot, p) in w.iter_mut().zip(&parts) {
            *slot = p.trim().parse().ok()?;
        }
        if w.iter().sum::<u32>() == 0 {
            return None;
        }
        Some(Self {
            get: w[0],
            put: w[1],
            cas: w[2],
            gather: w[3],
        })
    }

    pub fn total(&self) -> u32 {
        self.get + self.put + self.cas + self.gather
    }
}

/// One logical serving request, keys resolved (0-based, tenant-local).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Request {
    Get(u64),
    Put(u64),
    Cas(u64),
    /// An embedding bag: the rows to fold with one near-memory
    /// `gather_sum` program (duplicates allowed, as in real bags).
    Gather(Vec<u64>),
}

/// The `idx`-th decorrelated stream seed derived from one run seed —
/// SplitMix64's `idx`-th output, the generator's intended use for
/// spawning independent streams.
pub fn stream_seed(seed: u64, idx: u64) -> u64 {
    SplitMix64::new(seed.wrapping_add(idx.wrapping_mul(0x9E37_79B9_7F4A_7C15))).next_u64()
}

/// A tenant's private open-loop request stream.
#[derive(Debug, Clone)]
pub struct TenantWorkload {
    rng: Xoshiro256,
    zipf: Zipf,
    mix: Mix,
    bag: usize,
}

impl TenantWorkload {
    /// Build tenant `idx`'s stream over `keys` keys at Zipf skew `theta`
    /// (`0.0` = uniform). `bag` rows per GATHER; must stay within the
    /// packet-program step budget (the runner validates that).
    pub fn new(seed: u64, idx: usize, keys: u64, theta: f64, mix: Mix, bag: usize) -> Self {
        assert!(mix.total() > 0, "request mix must have a positive weight");
        assert!(bag >= 1, "gather bags need at least one row");
        Self {
            rng: Xoshiro256::seed_from(stream_seed(seed, idx as u64)),
            zipf: Zipf::new(keys, theta),
            mix,
            bag,
        }
    }

    /// Draw the next request.
    pub fn next_request(&mut self) -> Request {
        let w = self.rng.next_below(self.mix.total() as u64) as u32;
        if w < self.mix.get {
            Request::Get(self.zipf.sample(&mut self.rng))
        } else if w < self.mix.get + self.mix.put {
            Request::Put(self.zipf.sample(&mut self.rng))
        } else if w < self.mix.get + self.mix.put + self.mix.cas {
            Request::Cas(self.zipf.sample(&mut self.rng))
        } else {
            let rows = (0..self.bag).map(|_| self.zipf.sample(&mut self.rng)).collect();
            Request::Gather(rows)
        }
    }

    pub fn keys(&self) -> u64 {
        self.zipf.keys()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mix_parses_and_rejects() {
        assert_eq!(Mix::parse("60/25/10/5"), Some(Mix::serving_default()));
        assert_eq!(
            Mix::parse(" 1/0/0/0 "), // whitespace around parts trims away
            Some(Mix { get: 1, put: 0, cas: 0, gather: 0 })
        );
        assert_eq!(Mix::parse("0/0/0/0"), None);
        assert_eq!(Mix::parse("60/25/10"), None);
        assert_eq!(Mix::parse("a/b/c/d"), None);
    }

    #[test]
    fn streams_are_deterministic_and_tenant_private() {
        let mk = |idx| TenantWorkload::new(0xFEED, idx, 512, 0.99, Mix::serving_default(), 4);
        let walk = |mut w: TenantWorkload| -> Vec<Request> {
            (0..64).map(|_| w.next_request()).collect()
        };
        // Same seed + tenant index replays the identical sequence.
        assert_eq!(walk(mk(0)), walk(mk(0)));
        // A different tenant index yields a different stream.
        assert_ne!(walk(mk(0)), walk(mk(1)));
    }

    #[test]
    fn requests_respect_key_space_and_bag_size() {
        let mut w = TenantWorkload::new(7, 3, 100, 1.1, Mix::serving_default(), 5);
        let mut saw_gather = false;
        for _ in 0..2000 {
            match w.next_request() {
                Request::Get(k) | Request::Put(k) | Request::Cas(k) => assert!(k < 100),
                Request::Gather(rows) => {
                    saw_gather = true;
                    assert_eq!(rows.len(), 5);
                    assert!(rows.iter().all(|&k| k < 100));
                }
            }
        }
        assert!(saw_gather, "5/100 gather weight never fired in 2000 draws");
    }

    #[test]
    fn degenerate_mix_emits_only_that_op() {
        let mix = Mix { get: 0, put: 1, cas: 0, gather: 0 };
        let mut w = TenantWorkload::new(1, 0, 10, 0.0, mix, 1);
        for _ in 0..100 {
            assert!(matches!(w.next_request(), Request::Put(_)));
        }
    }
}
