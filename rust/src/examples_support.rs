//! Shared driver for the end-to-end example: data-parallel MLP training
//! with the gradient allreduce routed through the simulated NetDAM fabric.
//!
//! The full loop per step:
//! 1. every worker executes the `mlp_grad` artifact through PJRT (L2
//!    compute, python-free at runtime);
//! 2. the flattened gradients are written into the 4 simulated NetDAM
//!    devices and ring-allreduced by in-memory packet programs
//!    (`reduce → guarded_write → store`, the paper's §3 datapath) — the
//!    real gradient bits flow through the DES and the device ALUs;
//! 3. the reduced sum is scaled by 1/workers and applied via the
//!    `sgd_apply` artifact (Pallas SIMD kernels — the "in-memory
//!    optimizer").
//!
//! Workers intentionally compute on the *same* batch so the resulting
//! curve is comparable to the single-worker python oracle
//! (`artifacts/reference_curve.txt`): allreduce-sum of `w` identical
//! gradients scaled by `1/w` recovers the oracle's gradient up to f32
//! ring-order rounding.

use anyhow::{ensure, Context, Result};

use crate::collectives::{read_vector, run_ring_allreduce, RingSpec};
use crate::isa::registry::MemAccess;
use crate::net::{Cluster, LinkConfig, Topology};
use crate::runtime::mlp::MlpTrainer;
use crate::sim::{fmt_ns, Engine, SimTime};
use crate::util::bytes::f32s_to_bytes;

/// Train for `steps`; returns the loss curve. `verbose` prints a table.
pub fn train_dataparallel(steps: usize, workers: usize, verbose: bool) -> Result<Vec<f32>> {
    ensure!(workers >= 2, "data parallelism needs >= 2 workers");
    let mut trainer =
        MlpTrainer::open("artifacts").context("run `make artifacts` first")?;
    let n_params = trainer.shape.n_params();
    let lens = trainer.shape.param_lens();
    // Pad the flat gradient vector so it splits into whole SIMD blocks
    // across the ranks.
    let chunk = workers * crate::runtime::LANES;
    let padded = n_params.div_ceil(chunk) * chunk;

    let mut curve = Vec::with_capacity(steps);
    let mut fabric_ns_total: SimTime = 0;
    if verbose {
        println!("| step | loss | allreduce (sim) | retransmits |");
        println!("|---|---|---|---|");
    }
    for step in 0..steps {
        // --- worker compute (identical batch ⇒ oracle-comparable) -----
        let (x, y) = trainer.batch(step as u32)?;
        let (grads, loss) = trainer.grad_step(&x, &y)?;
        let mut flat = Vec::with_capacity(padded);
        for g in &grads {
            flat.extend_from_slice(g);
        }
        flat.resize(padded, 0.0);

        // --- gradient allreduce through the NetDAM fabric --------------
        let t = Topology::star(0xE2E + step as u64, workers, 0, LinkConfig::dc_100g());
        let mut cl = t.cluster;
        let devices = t.devices;
        let bytes = f32s_to_bytes(&flat);
        for &d in &devices {
            cl.device_mut(d).mem().write(0, &bytes)?;
        }
        let spec = RingSpec {
            elements: padded,
            window: 8,
            ..Default::default()
        };
        let mut eng: Engine<Cluster> = Engine::new();
        let out = run_ring_allreduce(&mut cl, &mut eng, &devices, &spec)?;
        ensure!(out.blocks_done == out.blocks, "allreduce incomplete");
        fabric_ns_total += out.elapsed_ns;
        let summed = read_vector(&mut cl, devices[0], 0, padded)?;

        // --- average + SGD via the Pallas artifact ---------------------
        let inv = 1.0 / workers as f32;
        let mut avg = Vec::with_capacity(4);
        let mut off = 0;
        for &len in &lens {
            avg.push(summed[off..off + len].iter().map(|v| v * inv).collect::<Vec<f32>>());
            off += len;
        }
        trainer.sgd_apply(&avg, 0.05)?;
        curve.push(loss);
        if verbose && (step < 5 || step % 10 == 0 || step == steps - 1) {
            println!(
                "| {step} | {loss:.6} | {} | {} |",
                fmt_ns(out.elapsed_ns),
                out.retransmits
            );
        }
    }
    if verbose {
        println!(
            "total simulated fabric time for {steps} allreduces: {}",
            fmt_ns(fabric_ns_total)
        );
        // Compare against the python oracle when available.
        if let Ok(reference) = MlpTrainer::reference_curve("artifacts") {
            let n = reference.len().min(curve.len());
            let max_rel = (0..n)
                .map(|i| ((curve[i] - reference[i]) / reference[i].max(1e-9)).abs())
                .fold(0.0f32, f32::max);
            println!(
                "oracle check: max relative loss deviation over {n} steps = {max_rel:.2e}"
            );
        }
    }
    Ok(curve)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    #[ignore = "needs artifacts/ (make artifacts) and ~20s"]
    fn training_matches_python_oracle() {
        let curve = train_dataparallel(10, 4, false).unwrap();
        let reference = MlpTrainer::reference_curve("artifacts").unwrap();
        for i in 0..10 {
            let rel = ((curve[i] - reference[i]) / reference[i]).abs();
            assert!(rel < 1e-3, "step {i}: {} vs {} ({rel})", curve[i], reference[i]);
        }
    }
}
