//! SROU path planning (paper §2.3 and the Ruta draft).
//!
//! The *header* lives in [`crate::wire::srou_hdr`]; this module builds the
//! segment lists: ring chains for the collectives (§3), spine-pinned
//! multipath plans (E4), and general function-chaining for DAG dataflow.

use crate::wire::{DeviceIp, Segment, SrouHeader};

/// Segment list that walks `ips[start+1], ips[start+2], ... , ips[start+k]`
/// around a logical ring (the reduce-scatter chain for the chunk owned by
/// rank `start`). `k = ips.len()-1` visits every *other* rank once.
pub fn ring_chain(ips: &[DeviceIp], start: usize, hops: usize) -> SrouHeader {
    // `hops` may exceed the ring size: the fused allreduce walks the ring
    // almost twice (2·(N−1) hops). Only the wire header caps the length.
    assert!(!ips.is_empty() && hops >= 1);
    assert!(
        hops <= crate::wire::srou_hdr::MAX_SEGMENTS,
        "{hops} hops exceed the SROU stack"
    );
    let n = ips.len();
    let segs: Vec<Segment> = (1..=hops)
        .map(|i| Segment::to(ips[(start + i) % n]))
        .collect();
    SrouHeader::through(segs)
}

/// Full ring for rank `start`: every other rank exactly once (N−1 hops).
pub fn full_ring(ips: &[DeviceIp], start: usize) -> SrouHeader {
    ring_chain(ips, start, ips.len() - 1)
}

/// A source-routed multipath plan: packet `i` is pinned through
/// `spines[i % spines.len()]` on its way to `dst` — per-packet spraying
/// decided at the *source*, the paper's alternative to in-fabric ECMP.
#[derive(Debug, Clone)]
pub struct SprayPlan {
    spines: Vec<DeviceIp>,
    next: usize,
}

impl SprayPlan {
    pub fn new(spines: Vec<DeviceIp>) -> Self {
        assert!(!spines.is_empty());
        Self { spines, next: 0 }
    }

    /// The path for the next packet toward `dst`.
    pub fn path(&mut self, dst: DeviceIp) -> SrouHeader {
        let spine = self.spines[self.next];
        self.next = (self.next + 1) % self.spines.len();
        SrouHeader::through(vec![Segment::to(spine), Segment::to(dst)])
    }

    /// Pin every packet through one fixed spine (the "single path" arm of
    /// experiment E4).
    pub fn pinned(spine: DeviceIp, dst: DeviceIp) -> SrouHeader {
        SrouHeader::through(vec![Segment::to(spine), Segment::to(dst)])
    }
}

/// Chain arbitrary (node, function) pairs — the DAG / dataflow use case
/// ("Segment Routing Header could be a chaining function to processing
/// packet on different node").
pub fn chain(stages: &[(DeviceIp, u16)]) -> SrouHeader {
    SrouHeader::through(
        stages
            .iter()
            .map(|&(ip, f)| Segment::call(ip, f))
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ips(n: u8) -> Vec<DeviceIp> {
        (1..=n).map(DeviceIp::lan).collect()
    }

    #[test]
    fn full_ring_visits_everyone_once() {
        let v = ips(4);
        let h = full_ring(&v, 0);
        let visited: Vec<DeviceIp> = h.segments.iter().map(|s| s.node).collect();
        assert_eq!(visited, vec![v[1], v[2], v[3]]);
    }

    #[test]
    fn ring_wraps_around() {
        let v = ips(4);
        let h = full_ring(&v, 2);
        let visited: Vec<DeviceIp> = h.segments.iter().map(|s| s.node).collect();
        assert_eq!(visited, vec![v[3], v[0], v[1]]);
    }

    #[test]
    fn every_start_covers_all_other_ranks() {
        let v = ips(7);
        for start in 0..7 {
            let h = full_ring(&v, start);
            let mut seen: Vec<u32> = h.segments.iter().map(|s| s.node.0).collect();
            seen.sort_unstable();
            let mut expect: Vec<u32> =
                (0..7).filter(|&i| i != start).map(|i| v[i].0).collect();
            expect.sort_unstable();
            assert_eq!(seen, expect);
        }
    }

    #[test]
    fn spray_alternates_spines() {
        let mut plan = SprayPlan::new(vec![DeviceIp::lan(201), DeviceIp::lan(202)]);
        let d = DeviceIp::lan(9);
        let p1 = plan.path(d);
        let p2 = plan.path(d);
        let p3 = plan.path(d);
        assert_eq!(p1.segments[0].node, DeviceIp::lan(201));
        assert_eq!(p2.segments[0].node, DeviceIp::lan(202));
        assert_eq!(p3.segments[0].node, DeviceIp::lan(201));
        // All terminate at dst.
        for p in [p1, p2, p3] {
            assert_eq!(p.segments.last().unwrap().node, d);
        }
    }

    #[test]
    fn chain_carries_functions() {
        let h = chain(&[(DeviceIp::lan(2), 7), (DeviceIp::lan(3), 9)]);
        assert_eq!(h.segments[0].func, 7);
        assert_eq!(h.segments[1].func, 9);
        assert_eq!(h.hops_remaining(), 2);
    }
}
