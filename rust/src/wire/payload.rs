//! Packet payloads: real bytes, a small inline scalar, or a phantom length.
//!
//! The full paper-scale experiment (E2: 2 GiB allreduce) would need ~8 GiB
//! of payload buffers if every in-flight packet carried real data. The DES
//! therefore supports three payload modes:
//!
//! * [`Payload::Data`] — real bytes (`Arc`-shared so store-and-forward
//!   hops don't copy). All correctness tests run in this mode; the ALU
//!   actually computes.
//! * [`Payload::Inline`] — up to 8 bytes stored in the enum itself. The
//!   empty payload and the forwarded-scalar shape (`from_u64`, e.g. a
//!   `BlockHash` digest) are by far the most-constructed payloads (every
//!   ack/done/reply packet), and neither deserves an `Arc<Vec>` — inline
//!   storage keeps them heap-allocation-free on the DES hot path.
//! * [`Payload::Phantom`] — length only. Timing-exact, contents elided;
//!   used for paper-scale timing runs. ALU cost is still charged.
//!
//! Equality is by *content*, not representation: an 8-byte `Data` equals
//! the same 8 bytes `Inline` (the codec is free to pick either).

use std::sync::Arc;

use anyhow::Result;

use crate::util::bytes::{bytes_to_f32s, f32s_to_bytes};

/// Capacity of the inline representation.
pub const INLINE_CAP: usize = 8;

#[derive(Debug, Clone)]
pub enum Payload {
    /// Real data, shared between hops.
    Data(Arc<Vec<u8>>),
    /// Up to [`INLINE_CAP`] real bytes stored inline (no heap).
    Inline { len: u8, buf: [u8; INLINE_CAP] },
    /// Timing-only payload of the given byte length.
    Phantom(u32),
}

impl Payload {
    /// The empty payload. Allocation-free (inline representation).
    pub fn empty() -> Self {
        Payload::Inline {
            len: 0,
            buf: [0; INLINE_CAP],
        }
    }

    pub fn from_bytes(v: Vec<u8>) -> Self {
        Payload::Data(Arc::new(v))
    }

    pub fn from_f32s(xs: &[f32]) -> Self {
        Payload::Data(Arc::new(f32s_to_bytes(xs)))
    }

    /// A single little-endian u64 — the shape program steps forward
    /// scalar results in (e.g. a `BlockHash` step's digest).
    /// Allocation-free (inline representation).
    pub fn from_u64(v: u64) -> Self {
        Payload::Inline {
            len: INLINE_CAP as u8,
            buf: v.to_le_bytes(),
        }
    }

    pub fn phantom(len: usize) -> Self {
        Payload::Phantom(len as u32)
    }

    /// Length in bytes (what the wire charges).
    pub fn len(&self) -> usize {
        match self {
            Payload::Data(d) => d.len(),
            Payload::Inline { len, .. } => *len as usize,
            Payload::Phantom(n) => *n as usize,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn is_phantom(&self) -> bool {
        matches!(self, Payload::Phantom(_))
    }

    /// Borrow the bytes; `None` for phantom payloads.
    pub fn bytes(&self) -> Option<&[u8]> {
        match self {
            Payload::Data(d) => Some(d),
            Payload::Inline { len, buf } => Some(&buf[..*len as usize]),
            Payload::Phantom(_) => None,
        }
    }

    /// Decode as f32 lanes; `None` for phantom.
    pub fn f32s(&self) -> Option<Result<Vec<f32>>> {
        self.bytes().map(bytes_to_f32s)
    }
}

/// Content equality: phantoms match phantoms by length; data payloads
/// match by bytes regardless of `Data` vs `Inline` representation.
impl PartialEq for Payload {
    fn eq(&self, other: &Self) -> bool {
        match (self, other) {
            (Payload::Phantom(a), Payload::Phantom(b)) => a == b,
            (Payload::Phantom(_), _) | (_, Payload::Phantom(_)) => false,
            _ => self.bytes() == other.bytes(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn data_payload_round_trips_f32() {
        let xs = vec![1.0f32, 2.5, -3.0];
        let p = Payload::from_f32s(&xs);
        assert_eq!(p.len(), 12);
        assert_eq!(p.f32s().unwrap().unwrap(), xs);
    }

    #[test]
    fn u64_payload_is_8_le_bytes() {
        let p = Payload::from_u64(0x0102_0304_0506_0708);
        assert_eq!(p.len(), 8);
        assert_eq!(p.bytes().unwrap(), 0x0102_0304_0506_0708u64.to_le_bytes());
    }

    #[test]
    fn phantom_has_length_but_no_bytes() {
        let p = Payload::phantom(9000);
        assert_eq!(p.len(), 9000);
        assert!(p.bytes().is_none());
        assert!(p.is_phantom());
    }

    #[test]
    fn clone_is_shallow_for_data() {
        let p = Payload::from_bytes(vec![0u8; 4096]);
        let q = p.clone();
        if let (Payload::Data(a), Payload::Data(b)) = (&p, &q) {
            assert!(Arc::ptr_eq(a, b));
        } else {
            panic!("expected data payloads");
        }
    }

    #[test]
    fn empty_and_scalar_are_inline() {
        assert!(matches!(Payload::empty(), Payload::Inline { len: 0, .. }));
        assert!(matches!(
            Payload::from_u64(7),
            Payload::Inline { len: 8, .. }
        ));
    }

    #[test]
    fn equality_is_by_content_across_representations() {
        let v = 0xDEAD_BEEF_u64;
        let inline = Payload::from_u64(v);
        let heap = Payload::from_bytes(v.to_le_bytes().to_vec());
        assert_eq!(inline, heap);
        assert_eq!(Payload::empty(), Payload::from_bytes(Vec::new()));
        assert_ne!(Payload::empty(), Payload::phantom(0), "phantom is a mode");
        assert_ne!(inline, Payload::from_u64(v + 1));
    }
}
