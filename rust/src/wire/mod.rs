//! The NetDAM wire format (paper §2.2, Figure 3).
//!
//! A NetDAM packet rides in UDP/IPv4/Ethernet and carries:
//!
//! ```text
//! | Sequence | Segment Routing Header | Instruction | Address | Data |
//! ```
//!
//! * **Sequence** — packet ordering and (optional) reliable transmit.
//! * **Segment Routing Header** — SROU: a stack of (device, function)
//!   segments enabling topology-independent multipath *and* chained
//!   computation (the DAG/dataflow model; §2.3).
//! * **Instruction + Address** — see [`crate::isa`]; the address field is
//!   encoded inside the instruction operands.
//! * **Data** — up to 9000 B jumbo payload ≈ 2048 × f32 SIMD lanes.
//!
//! [`packet::Packet`] is the structured form the simulator passes around;
//! [`packet::Packet::encode`]/[`decode`](packet::Packet::decode) give the
//! exact byte representation (tested round-trip + fuzz), and
//! [`packet::Packet::wire_bytes`] is what the timing models charge.

pub mod frame;
pub mod packet;
pub mod payload;
pub mod srou_hdr;

pub use frame::{DeviceIp, ETH_OVERHEAD, IPV4_HEADER, UDP_HEADER, WIRE_OVERHEAD};
pub use packet::{AggEntry, AggMeta, Packet, MAX_AGG_ENTRIES};
pub use payload::Payload;
pub use srou_hdr::{SegVec, Segment, SrouHeader, FUNC_NONE};
