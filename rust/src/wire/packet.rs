//! The NetDAM packet: structured form + exact byte codec.

use anyhow::{bail, Result};

use super::frame::{CarrierHeader, DeviceIp, UDP_HEADER, WIRE_OVERHEAD};
use super::payload::Payload;
use super::srou_hdr::SrouHeader;
use crate::isa::{Flags, Instruction};
use crate::util::bytes::{Reader, Writer};

/// Maximum NetDAM data payload: 9000 B jumbo frame budget minus carrier
/// and NetDAM headers leaves room for 2048 × f32 = 8192 B SIMD blocks.
pub const MAX_PAYLOAD: usize = 8832;
/// The paper's SIMD block: 2048 × f32.
pub const SIMD_LANES: usize = 2048;
pub const SIMD_BLOCK_BYTES: usize = SIMD_LANES * 4;

/// A NetDAM packet as the simulator passes it around.
#[derive(Debug, Clone, PartialEq)]
pub struct Packet {
    /// Source device (fills the IPv4 source on the wire).
    pub src: DeviceIp,
    /// Sequence number — ordering + reliable transmit (§2.2).
    pub seq: u64,
    /// Segment routing header; `srou.current()` is where it's headed.
    pub srou: SrouHeader,
    /// The instruction (includes the Address operand).
    pub instr: Instruction,
    pub flags: Flags,
    /// SIMD data payload.
    pub payload: Payload,
}

impl Packet {
    pub fn new(src: DeviceIp, seq: u64, srou: SrouHeader, instr: Instruction) -> Self {
        Packet {
            src,
            seq,
            srou,
            instr,
            flags: Flags::default(),
            payload: Payload::empty(),
        }
    }

    pub fn with_flags(mut self, flags: Flags) -> Self {
        self.flags = flags;
        self
    }

    pub fn with_payload(mut self, payload: Payload) -> Self {
        debug_assert!(payload.len() <= MAX_PAYLOAD, "payload exceeds jumbo MTU");
        self.payload = payload;
        self
    }

    /// The device this packet is currently routed toward.
    pub fn dst(&self) -> Option<DeviceIp> {
        self.srou.current().map(|s| s.node)
    }

    /// NetDAM header length (sequence + SROU + instruction + length field).
    fn netdam_header_len(&self) -> usize {
        // seq(8) + srou + instr is variable; measure by encoding.
        let mut w = Writer::with_capacity(64);
        w.u64(self.seq);
        self.srou.encode(&mut w);
        self.instr.encode(self.flags, &mut w);
        w.u32(0); // payload length field
        w.len()
    }

    /// Total bytes this packet occupies on a link, including Ethernet/IP/
    /// UDP overhead and preamble+IFG — the number the timing model charges.
    pub fn wire_bytes(&self) -> usize {
        WIRE_OVERHEAD + self.netdam_header_len() + self.payload.len()
    }

    /// Encode the full IPv4+UDP+NetDAM byte image (no Ethernet MAC bytes —
    /// the examples exchange L3 datagrams). Phantom payloads cannot be
    /// encoded (they exist only inside the DES).
    pub fn encode(&self) -> Result<Vec<u8>> {
        let Some(data) = self.payload.bytes() else {
            bail!("cannot encode a phantom payload to bytes");
        };
        let mut body = Writer::with_capacity(64 + data.len());
        body.u64(self.seq);
        self.srou.encode(&mut body);
        self.instr.encode(self.flags, &mut body);
        body.u32(data.len() as u32);
        body.bytes(data);
        let body = body.into_vec();

        let dst = self
            .dst()
            .ok_or_else(|| anyhow::anyhow!("packet has no remaining segment"))?;
        let mut w = Writer::with_capacity(body.len() + 28);
        CarrierHeader {
            src: self.src,
            dst,
            udp_len: (UDP_HEADER + body.len()) as u16,
            // A switch-applied CE mark rides the IPv4 TOS byte so
            // ECN-blind middleboxes and DCQCN receivers both see it.
            ecn: self.flags.ecn(),
        }
        .encode(&mut w);
        w.bytes(&body);
        Ok(w.into_vec())
    }

    /// Decode from the byte image produced by [`encode`](Self::encode).
    pub fn decode(bytes: &[u8]) -> Result<Packet> {
        let mut r = Reader::new(bytes);
        let carrier = CarrierHeader::decode(&mut r)?;
        let seq = r.u64()?;
        let srou = SrouHeader::decode(&mut r)?;
        let (instr, mut flags) = Instruction::decode(&mut r)?;
        if carrier.ecn {
            // An L3-only marker (a real switch) sets the TOS bits without
            // touching the NetDAM flags — fold the mark back in.
            flags = flags.with(Flags::ECN);
        }
        let plen = r.u32()? as usize;
        if plen > MAX_PAYLOAD {
            bail!("payload length {plen} exceeds MTU budget");
        }
        let data = r.slice(plen)?.to_vec();
        if r.remaining() != 0 {
            bail!("{} trailing bytes after payload", r.remaining());
        }
        let pkt = Packet {
            src: carrier.src,
            seq,
            srou,
            instr,
            flags,
            payload: Payload::from_bytes(data),
        };
        // Cross-check carrier routing against the SROU stack.
        if let Some(dst) = pkt.dst() {
            if dst != carrier.dst {
                bail!("carrier dst {} != SROU current {}", carrier.dst, dst);
            }
        }
        Ok(pkt)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::SimdOp;
    use crate::wire::srou_hdr::Segment;

    fn ip(x: u8) -> DeviceIp {
        DeviceIp::lan(x)
    }

    #[test]
    fn encode_decode_round_trip() {
        let pkt = Packet::new(
            ip(1),
            42,
            SrouHeader::through(vec![Segment::call(ip(2), 5), Segment::to(ip(3))]),
            Instruction::Simd {
                op: SimdOp::Add,
                addr: 0x8000,
            },
        )
        .with_flags(Flags(Flags::RELIABLE))
        .with_payload(Payload::from_f32s(&[1.0, 2.0, 3.0, 4.0]));
        let bytes = pkt.encode().unwrap();
        let back = Packet::decode(&bytes).unwrap();
        assert_eq!(back, pkt);
    }

    #[test]
    fn wire_bytes_matches_encoding_plus_l2() {
        let pkt = Packet::new(
            ip(1),
            7,
            SrouHeader::direct(ip(2)),
            Instruction::Read { addr: 0, len: 128 },
        );
        let encoded = pkt.encode().unwrap().len();
        // encode() covers IP+UDP+NetDAM; wire adds Ethernet 18 + gap 20.
        assert_eq!(pkt.wire_bytes(), encoded + 38);
    }

    #[test]
    fn simd_read_request_is_small() {
        // E1's request packet: READ of 32 × f32. The request itself
        // carries no payload — it must be well under 200 B on the wire.
        let pkt = Packet::new(
            ip(1),
            1,
            SrouHeader::direct(ip(2)),
            Instruction::Read { addr: 0, len: 128 },
        );
        assert!(pkt.wire_bytes() < 120, "got {}", pkt.wire_bytes());
    }

    #[test]
    fn jumbo_block_fits_mtu() {
        let pkt = Packet::new(
            ip(1),
            1,
            SrouHeader::direct(ip(2)),
            Instruction::Write { addr: 0 },
        )
        .with_payload(Payload::from_bytes(vec![0; SIMD_BLOCK_BYTES]));
        assert!(pkt.wire_bytes() <= 9000 + 38, "got {}", pkt.wire_bytes());
    }

    #[test]
    fn phantom_cannot_encode_but_has_timing() {
        let pkt = Packet::new(
            ip(1),
            1,
            SrouHeader::direct(ip(2)),
            Instruction::Write { addr: 0 },
        )
        .with_payload(Payload::phantom(8192));
        assert!(pkt.encode().is_err());
        assert!(pkt.wire_bytes() > 8192);
    }

    #[test]
    fn program_packet_round_trips() {
        // A full §3 fused-ring program rides the ordinary packet codec.
        use crate::isa::ProgramBuilder;
        let prog = ProgramBuilder::new()
            .reduce(SimdOp::Add, 0x1000, 3)
            .guarded_write(0x1000, 7)
            .store(0x1000, 3)
            .on_retire(9)
            .build_unchecked();
        let segs: Vec<Segment> = (2u8..8).map(|i| Segment::to(ip(i))).collect();
        let pkt = Packet::new(
            ip(1),
            11,
            SrouHeader::through(segs),
            Instruction::Program(Box::new(prog)),
        )
        .with_payload(Payload::from_f32s(&[1.5; 16]));
        let bytes = pkt.encode().unwrap();
        assert_eq!(Packet::decode(&bytes).unwrap(), pkt);
    }

    #[test]
    fn ecn_flag_survives_the_carrier_header() {
        let pkt = Packet::new(
            ip(1),
            5,
            SrouHeader::direct(ip(2)),
            Instruction::Write { addr: 0 },
        )
        .with_flags(Flags::default().with(Flags::ECN))
        .with_payload(Payload::from_bytes(vec![7u8; 16]));
        let bytes = pkt.encode().unwrap();
        // The IPv4 TOS byte (offset 1) carries the CE codepoint.
        assert_eq!(bytes[1] & 0b11, 0b11, "CE mark in the IP header");
        let back = Packet::decode(&bytes).unwrap();
        assert!(back.flags.ecn());
        assert_eq!(back, pkt);
    }

    #[test]
    fn trailing_garbage_rejected() {
        let pkt = Packet::new(
            ip(1),
            3,
            SrouHeader::direct(ip(2)),
            Instruction::Nop,
        );
        let mut bytes = pkt.encode().unwrap();
        bytes.push(0xFF);
        assert!(Packet::decode(&bytes).is_err());
    }

    #[test]
    fn fuzz_decode_never_panics() {
        let mut rng = crate::util::Xoshiro256::seed_from(0xF077);
        let base = Packet::new(
            ip(1),
            9,
            SrouHeader::direct(ip(2)),
            Instruction::Read { addr: 64, len: 32 },
        )
        .encode()
        .unwrap();
        for _ in 0..2000 {
            let mut m = base.clone();
            let idx = rng.next_below(m.len() as u64) as usize;
            m[idx] ^= (rng.next_u64() & 0xFF) as u8;
            let _ = Packet::decode(&m); // must not panic
        }
        for _ in 0..500 {
            let n = rng.next_below(128) as usize;
            let junk: Vec<u8> = (0..n).map(|_| (rng.next_u64() & 0xFF) as u8).collect();
            let _ = Packet::decode(&junk);
        }
    }
}
