//! The NetDAM packet: structured form + exact byte codec.
//!
//! The in-memory form is tuned for the DES hot path: the heavy parts of
//! a packet — the payload bytes, the aggregation manifest, a carried
//! program — are all behind `Arc`s, so cloning a packet for fan-out,
//! retransmit buffering, or a duplicate-delivery fault is a few refcount
//! bumps plus a `memcpy` of the inline header (the SROU segment list is
//! a fixed array). Hops that genuinely mutate shared state (an AGG
//! manifest merge, a program-counter advance) go copy-on-write via
//! `Arc::make_mut`.

use std::sync::Arc;

use anyhow::{bail, Result};

use super::frame::{CarrierHeader, DeviceIp, UDP_HEADER, WIRE_OVERHEAD};
use super::payload::Payload;
use super::srou_hdr::SrouHeader;
use crate::isa::{Flags, Instruction, SimdOp};
use crate::util::bytes::{Reader, Writer};

/// Maximum NetDAM data payload: 9000 B jumbo frame budget minus carrier
/// and NetDAM headers leaves room for 2048 × f32 = 8192 B SIMD blocks.
pub const MAX_PAYLOAD: usize = 8832;
/// The paper's SIMD block: 2048 × f32.
pub const SIMD_LANES: usize = 2048;
pub const SIMD_BLOCK_BYTES: usize = SIMD_LANES * 4;

/// Hard cap on manifest entries an aggregated packet may carry (a full
/// fat-tree pod plus a spine-merged set stays far below this).
pub const MAX_AGG_ENTRIES: usize = 1024;

/// One contribution folded into an aggregated payload: which device
/// injected it, under which transport identity, and which completion id
/// the collective driver is waiting on for it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AggEntry {
    pub src: DeviceIp,
    /// The contributor's own injected sequence number — the root echoes
    /// it back per entry so each sender's reliability slot clears.
    pub seq: u64,
    /// Driver completion id (`CollectiveDone { block }`) for this entry.
    pub done_id: u32,
}

/// Aggregation metadata riding a [`Flags::AGG`]-marked packet (§2.5
/// switch compute): the slot key, the commutative reduce op, and the
/// manifest of contributions already folded into the payload. Switches
/// union manifests when they merge; the root collector dedupes and
/// completes per entry.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AggMeta {
    /// Tenant owning the collective (switch ACL check key).
    pub tenant: u32,
    /// Aggregation group: all contributions to one (collective, block)
    /// share it (planners use the block's first done-id, globally unique).
    pub group: u32,
    /// The commutative SIMD reduce the switch applies when merging.
    pub op: SimdOp,
    pub entries: Vec<AggEntry>,
}

impl AggMeta {
    pub fn encode(&self, w: &mut Writer) {
        w.u32(self.tenant);
        w.u32(self.group);
        w.u8(self.op as u8);
        w.u16(self.entries.len() as u16);
        for e in &self.entries {
            w.u32(e.src.0);
            w.u64(e.seq);
            w.u32(e.done_id);
        }
    }

    pub fn decode(r: &mut Reader) -> Result<AggMeta> {
        let tenant = r.u32()?;
        let group = r.u32()?;
        let op = SimdOp::from_u8(r.u8()?)?;
        let n = r.u16()? as usize;
        if n == 0 || n > MAX_AGG_ENTRIES {
            bail!("bad aggregation entry count {n}");
        }
        let mut entries = Vec::with_capacity(n);
        for _ in 0..n {
            entries.push(AggEntry {
                src: DeviceIp(r.u32()?),
                seq: r.u64()?,
                done_id: r.u32()?,
            });
        }
        Ok(AggMeta {
            tenant,
            group,
            op,
            entries,
        })
    }
}

/// A NetDAM packet as the simulator passes it around.
#[derive(Debug, Clone, PartialEq)]
pub struct Packet {
    /// Source device (fills the IPv4 source on the wire).
    pub src: DeviceIp,
    /// Sequence number — ordering + reliable transmit (§2.2).
    pub seq: u64,
    /// Segment routing header; `srou.current()` is where it's headed.
    pub srou: SrouHeader,
    /// The instruction (includes the Address operand).
    pub instr: Instruction,
    pub flags: Flags,
    /// Aggregation metadata; present iff [`Flags::AGG`] is set.
    /// `Arc`-shared: cloned packets (retransmit buffer, fan-out) share
    /// the manifest; switches merging manifests copy-on-write.
    pub agg: Option<Arc<AggMeta>>,
    /// SIMD data payload.
    pub payload: Payload,
}

impl Packet {
    pub fn new(src: DeviceIp, seq: u64, srou: SrouHeader, instr: Instruction) -> Self {
        Packet {
            src,
            seq,
            srou,
            instr,
            flags: Flags::default(),
            agg: None,
            payload: Payload::empty(),
        }
    }

    pub fn with_flags(mut self, flags: Flags) -> Self {
        self.flags = flags;
        self
    }

    pub fn with_payload(mut self, payload: Payload) -> Self {
        debug_assert!(payload.len() <= MAX_PAYLOAD, "payload exceeds jumbo MTU");
        self.payload = payload;
        self
    }

    /// Mark for in-network aggregation: sets [`Flags::AGG`] and attaches
    /// the metadata the switches and the root collector key on.
    pub fn with_agg(mut self, agg: AggMeta) -> Self {
        self.flags = self.flags.with(Flags::AGG);
        self.agg = Some(Arc::new(agg));
        self
    }

    /// The device this packet is currently routed toward.
    pub fn dst(&self) -> Option<DeviceIp> {
        self.srou.current().map(|s| s.node)
    }

    /// NetDAM header length (sequence + SROU + instruction + length field).
    fn netdam_header_len(&self) -> usize {
        // seq(8) + srou + instr is variable; measure by encoding.
        let mut w = Writer::with_capacity(64);
        w.u64(self.seq);
        self.srou.encode(&mut w);
        self.instr.encode(self.flags, &mut w);
        if let Some(agg) = &self.agg {
            agg.encode(&mut w);
        }
        w.u32(0); // payload length field
        w.len()
    }

    /// Total bytes this packet occupies on a link, including Ethernet/IP/
    /// UDP overhead and preamble+IFG — the number the timing model charges.
    pub fn wire_bytes(&self) -> usize {
        WIRE_OVERHEAD + self.netdam_header_len() + self.payload.len()
    }

    /// Encode the full IPv4+UDP+NetDAM byte image (no Ethernet MAC bytes —
    /// the examples exchange L3 datagrams). Phantom payloads cannot be
    /// encoded (they exist only inside the DES).
    pub fn encode(&self) -> Result<Vec<u8>> {
        let Some(data) = self.payload.bytes() else {
            bail!("cannot encode a phantom payload to bytes");
        };
        if self.flags.agg() != self.agg.is_some() {
            bail!("AGG flag and aggregation metadata must agree");
        }
        let mut body = Writer::with_capacity(64 + data.len());
        body.u64(self.seq);
        self.srou.encode(&mut body);
        self.instr.encode(self.flags, &mut body);
        if let Some(agg) = &self.agg {
            agg.encode(&mut body);
        }
        body.u32(data.len() as u32);
        body.bytes(data);
        let body = body.into_vec();

        let dst = self
            .dst()
            .ok_or_else(|| anyhow::anyhow!("packet has no remaining segment"))?;
        let mut w = Writer::with_capacity(body.len() + 28);
        CarrierHeader {
            src: self.src,
            dst,
            udp_len: (UDP_HEADER + body.len()) as u16,
            // A switch-applied CE mark rides the IPv4 TOS byte so
            // ECN-blind middleboxes and DCQCN receivers both see it.
            ecn: self.flags.ecn(),
        }
        .encode(&mut w);
        w.bytes(&body);
        Ok(w.into_vec())
    }

    /// Decode from the byte image produced by [`encode`](Self::encode).
    pub fn decode(bytes: &[u8]) -> Result<Packet> {
        let mut r = Reader::new(bytes);
        let carrier = CarrierHeader::decode(&mut r)?;
        let seq = r.u64()?;
        let srou = SrouHeader::decode(&mut r)?;
        let (instr, mut flags) = Instruction::decode(&mut r)?;
        if carrier.ecn {
            // An L3-only marker (a real switch) sets the TOS bits without
            // touching the NetDAM flags — fold the mark back in.
            flags = flags.with(Flags::ECN);
        }
        let agg = if flags.agg() {
            Some(Arc::new(AggMeta::decode(&mut r)?))
        } else {
            None
        };
        let plen = r.u32()? as usize;
        if plen > MAX_PAYLOAD {
            bail!("payload length {plen} exceeds MTU budget");
        }
        let data = r.slice(plen)?.to_vec();
        if r.remaining() != 0 {
            bail!("{} trailing bytes after payload", r.remaining());
        }
        let pkt = Packet {
            src: carrier.src,
            seq,
            srou,
            instr,
            flags,
            agg,
            payload: Payload::from_bytes(data),
        };
        // Cross-check carrier routing against the SROU stack.
        if let Some(dst) = pkt.dst() {
            if dst != carrier.dst {
                bail!("carrier dst {} != SROU current {}", carrier.dst, dst);
            }
        }
        Ok(pkt)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::SimdOp;
    use crate::wire::srou_hdr::Segment;

    fn ip(x: u8) -> DeviceIp {
        DeviceIp::lan(x)
    }

    #[test]
    fn encode_decode_round_trip() {
        let pkt = Packet::new(
            ip(1),
            42,
            SrouHeader::through(vec![Segment::call(ip(2), 5), Segment::to(ip(3))]),
            Instruction::Simd {
                op: SimdOp::Add,
                addr: 0x8000,
            },
        )
        .with_flags(Flags(Flags::RELIABLE))
        .with_payload(Payload::from_f32s(&[1.0, 2.0, 3.0, 4.0]));
        let bytes = pkt.encode().unwrap();
        let back = Packet::decode(&bytes).unwrap();
        assert_eq!(back, pkt);
    }

    #[test]
    fn wire_bytes_matches_encoding_plus_l2() {
        let pkt = Packet::new(
            ip(1),
            7,
            SrouHeader::direct(ip(2)),
            Instruction::Read { addr: 0, len: 128 },
        );
        let encoded = pkt.encode().unwrap().len();
        // encode() covers IP+UDP+NetDAM; wire adds Ethernet 18 + gap 20.
        assert_eq!(pkt.wire_bytes(), encoded + 38);
    }

    #[test]
    fn simd_read_request_is_small() {
        // E1's request packet: READ of 32 × f32. The request itself
        // carries no payload — it must be well under 200 B on the wire.
        let pkt = Packet::new(
            ip(1),
            1,
            SrouHeader::direct(ip(2)),
            Instruction::Read { addr: 0, len: 128 },
        );
        assert!(pkt.wire_bytes() < 120, "got {}", pkt.wire_bytes());
    }

    #[test]
    fn jumbo_block_fits_mtu() {
        let pkt = Packet::new(
            ip(1),
            1,
            SrouHeader::direct(ip(2)),
            Instruction::Write { addr: 0 },
        )
        .with_payload(Payload::from_bytes(vec![0; SIMD_BLOCK_BYTES]));
        assert!(pkt.wire_bytes() <= 9000 + 38, "got {}", pkt.wire_bytes());
    }

    #[test]
    fn phantom_cannot_encode_but_has_timing() {
        let pkt = Packet::new(
            ip(1),
            1,
            SrouHeader::direct(ip(2)),
            Instruction::Write { addr: 0 },
        )
        .with_payload(Payload::phantom(8192));
        assert!(pkt.encode().is_err());
        assert!(pkt.wire_bytes() > 8192);
    }

    #[test]
    fn program_packet_round_trips() {
        // A full §3 fused-ring program rides the ordinary packet codec.
        use crate::isa::ProgramBuilder;
        let prog = ProgramBuilder::new()
            .reduce(SimdOp::Add, 0x1000, 3)
            .guarded_write(0x1000, 7)
            .store(0x1000, 3)
            .on_retire(9)
            .build_unchecked();
        let segs: Vec<Segment> = (2u8..8).map(|i| Segment::to(ip(i))).collect();
        let pkt = Packet::new(
            ip(1),
            11,
            SrouHeader::through(segs),
            Instruction::Program(Arc::new(prog)),
        )
        .with_payload(Payload::from_f32s(&[1.5; 16]));
        let bytes = pkt.encode().unwrap();
        assert_eq!(Packet::decode(&bytes).unwrap(), pkt);
    }

    #[test]
    fn ecn_flag_survives_the_carrier_header() {
        let pkt = Packet::new(
            ip(1),
            5,
            SrouHeader::direct(ip(2)),
            Instruction::Write { addr: 0 },
        )
        .with_flags(Flags::default().with(Flags::ECN))
        .with_payload(Payload::from_bytes(vec![7u8; 16]));
        let bytes = pkt.encode().unwrap();
        // The IPv4 TOS byte (offset 1) carries the CE codepoint.
        assert_eq!(bytes[1] & 0b11, 0b11, "CE mark in the IP header");
        let back = Packet::decode(&bytes).unwrap();
        assert!(back.flags.ecn());
        assert_eq!(back, pkt);
    }

    #[test]
    fn agg_marked_packet_round_trips_with_manifest() {
        let meta = AggMeta {
            tenant: 3,
            group: 41,
            op: SimdOp::Add,
            entries: vec![
                AggEntry {
                    src: ip(4),
                    seq: 900,
                    done_id: 41,
                },
                AggEntry {
                    src: ip(5),
                    seq: 77,
                    done_id: 42,
                },
            ],
        };
        let pkt = Packet::new(
            ip(4),
            900,
            SrouHeader::through(vec![Segment::call(ip(150), 2), Segment::to(ip(1))]),
            Instruction::Simd {
                op: SimdOp::Add,
                addr: 0x2000,
            },
        )
        .with_flags(Flags(Flags::RELIABLE))
        .with_agg(meta)
        .with_payload(Payload::from_f32s(&[2.0, 4.0]));
        assert!(pkt.flags.agg(), "with_agg sets the flag");
        let bytes = pkt.encode().unwrap();
        let back = Packet::decode(&bytes).unwrap();
        assert_eq!(back, pkt);
        // The manifest is charged to the wire like any header byte.
        assert!(pkt.wire_bytes() > pkt.payload.len() + WIRE_OVERHEAD + 16);
    }

    #[test]
    fn agg_flag_without_metadata_cannot_encode() {
        let pkt = Packet::new(
            ip(1),
            1,
            SrouHeader::direct(ip(2)),
            Instruction::Write { addr: 0 },
        )
        .with_flags(Flags(Flags::AGG));
        assert!(pkt.encode().is_err());
    }

    #[test]
    fn trailing_garbage_rejected() {
        let pkt = Packet::new(
            ip(1),
            3,
            SrouHeader::direct(ip(2)),
            Instruction::Nop,
        );
        let mut bytes = pkt.encode().unwrap();
        bytes.push(0xFF);
        assert!(Packet::decode(&bytes).is_err());
    }

    #[test]
    fn fuzz_decode_never_panics() {
        let mut rng = crate::util::Xoshiro256::seed_from(0xF077);
        let base = Packet::new(
            ip(1),
            9,
            SrouHeader::direct(ip(2)),
            Instruction::Read { addr: 64, len: 32 },
        )
        .encode()
        .unwrap();
        for _ in 0..2000 {
            let mut m = base.clone();
            let idx = rng.next_below(m.len() as u64) as usize;
            m[idx] ^= (rng.next_u64() & 0xFF) as u8;
            let _ = Packet::decode(&m); // must not panic
        }
        for _ in 0..500 {
            let n = rng.next_below(128) as usize;
            let junk: Vec<u8> = (0..n).map(|_| (rng.next_u64() & 0xFF) as u8).collect();
            let _ = Packet::decode(&junk);
        }
    }
}
