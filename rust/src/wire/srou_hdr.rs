//! Segment Routing over UDP (SROU) header (paper §2.2/§2.3, draft-zartbot-
//! sr-udp).
//!
//! The header is a stack of segments, each naming a NetDAM device and an
//! optional *function* to invoke there ("function callback could add in
//! segment routing stack for chaining computations over multiple node").
//! `left` is the classic SRv6-style Segments-Left pointer: it indexes the
//! *next* segment to process, counting down to 0 at the final destination.
//!
//! Ring Reduce-Scatter is literally a segment list `[n2:RS, n3:RS, n4:RS]`
//! — each hop executes the reduce function and self-routes onward.

use anyhow::{bail, Result};

use super::frame::DeviceIp;
use crate::util::bytes::{Reader, Writer};

/// "No function, just forward/deliver."
pub const FUNC_NONE: u16 = 0;

/// One segment: where to go, and what to run there.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Segment {
    pub node: DeviceIp,
    /// Function selector executed at this hop; `FUNC_NONE` = plain deliver.
    /// For collective packets the function is implied by the instruction,
    /// so this field doubles as a per-hop argument (e.g. chunk index).
    pub func: u16,
}

impl Segment {
    pub fn to(node: DeviceIp) -> Self {
        Segment {
            node,
            func: FUNC_NONE,
        }
    }

    pub fn call(node: DeviceIp, func: u16) -> Self {
        Segment { node, func }
    }
}

/// The SROU segment stack.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct SrouHeader {
    /// Segment list in travel order: `segments[0]` is the first hop.
    /// (SRv6 stores it reversed on the wire; we keep travel order in
    /// memory and reverse in the codec to stay faithful to the RFC style.)
    pub segments: Vec<Segment>,
    /// Index of the next segment to visit. `== segments.len()` means the
    /// packet hasn't departed; 0 means final delivery done.
    pub left: u8,
}

/// Hard cap (wire field is one byte; real SROU stacks are short).
pub const MAX_SEGMENTS: usize = 16;

impl SrouHeader {
    /// A direct path to one destination (degenerate single segment).
    pub fn direct(dst: DeviceIp) -> Self {
        Self::through(vec![Segment::to(dst)])
    }

    /// A path through the given segments, ready to travel.
    pub fn through(segments: Vec<Segment>) -> Self {
        assert!(
            (1..=MAX_SEGMENTS).contains(&segments.len()),
            "segment count {} out of range",
            segments.len()
        );
        let left = segments.len() as u8;
        Self { segments, left }
    }

    /// The segment the packet is currently travelling toward.
    pub fn current(&self) -> Option<Segment> {
        if self.left == 0 {
            return None;
        }
        self.segments
            .get(self.segments.len() - self.left as usize)
            .copied()
    }

    /// Advance the pointer after arriving at the current segment. Returns
    /// the *next* segment if any (i.e. the packet must be forwarded).
    pub fn advance(&mut self) -> Option<Segment> {
        if self.left == 0 {
            return None;
        }
        self.left -= 1;
        self.current()
    }

    /// Is the currently-targeted segment the last one?
    pub fn at_last_hop(&self) -> bool {
        self.left == 1
    }

    /// Remaining hops including the current target.
    pub fn hops_remaining(&self) -> usize {
        self.left as usize
    }

    pub fn encode(&self, w: &mut Writer) {
        w.u8(self.segments.len() as u8);
        w.u8(self.left);
        // Wire order is reversed (last segment first), SRv6-style.
        for seg in self.segments.iter().rev() {
            w.u32(seg.node.0);
            w.u16(seg.func);
        }
    }

    pub fn decode(r: &mut Reader) -> Result<SrouHeader> {
        let n = r.u8()? as usize;
        if n == 0 || n > MAX_SEGMENTS {
            bail!("bad segment count {n}");
        }
        let left = r.u8()?;
        if left as usize > n {
            bail!("segments-left {left} exceeds count {n}");
        }
        let mut segments = vec![
            Segment {
                node: DeviceIp(0),
                func: 0
            };
            n
        ];
        for i in (0..n).rev() {
            segments[i] = Segment {
                node: DeviceIp(r.u32()?),
                func: r.u16()?,
            };
        }
        Ok(SrouHeader { segments, left })
    }

    /// Encoded size in bytes.
    pub fn wire_len(&self) -> usize {
        2 + 6 * self.segments.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ip(x: u8) -> DeviceIp {
        DeviceIp::lan(x)
    }

    #[test]
    fn direct_header_travels_one_hop() {
        let mut h = SrouHeader::direct(ip(9));
        assert_eq!(h.current().unwrap().node, ip(9));
        assert!(h.at_last_hop());
        assert_eq!(h.advance(), None);
        assert_eq!(h.current(), None);
    }

    #[test]
    fn ring_traversal_order() {
        let mut h = SrouHeader::through(vec![
            Segment::call(ip(2), 1),
            Segment::call(ip(3), 2),
            Segment::call(ip(4), 3),
        ]);
        assert_eq!(h.hops_remaining(), 3);
        assert_eq!(h.current().unwrap().node, ip(2));
        assert!(!h.at_last_hop());
        let nxt = h.advance().unwrap();
        assert_eq!(nxt.node, ip(3));
        let nxt = h.advance().unwrap();
        assert_eq!(nxt.node, ip(4));
        assert!(h.at_last_hop());
        assert_eq!(h.advance(), None);
    }

    #[test]
    fn codec_round_trip_mid_flight() {
        let mut h = SrouHeader::through(vec![
            Segment::call(ip(2), 7),
            Segment::to(ip(3)),
            Segment::call(ip(4), 9),
        ]);
        h.advance(); // simulate one hop done
        let mut w = Writer::default();
        h.encode(&mut w);
        let v = w.into_vec();
        assert_eq!(v.len(), h.wire_len());
        let g = SrouHeader::decode(&mut Reader::new(&v)).unwrap();
        assert_eq!(g, h);
    }

    #[test]
    fn bad_headers_rejected() {
        // count 0
        assert!(SrouHeader::decode(&mut Reader::new(&[0, 0])).is_err());
        // left > count
        assert!(SrouHeader::decode(&mut Reader::new(&[1, 2, 0, 0, 0, 1, 0, 0])).is_err());
        // truncated segment
        assert!(SrouHeader::decode(&mut Reader::new(&[1, 1, 0, 0])).is_err());
    }
}
