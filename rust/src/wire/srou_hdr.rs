//! Segment Routing over UDP (SROU) header (paper §2.2/§2.3, draft-zartbot-
//! sr-udp).
//!
//! The header is a stack of segments, each naming a NetDAM device and an
//! optional *function* to invoke there ("function callback could add in
//! segment routing stack for chaining computations over multiple node").
//! `left` is the classic SRv6-style Segments-Left pointer: it indexes the
//! *next* segment to process, counting down to 0 at the final destination.
//!
//! Ring Reduce-Scatter is literally a segment list `[n2:RS, n3:RS, n4:RS]`
//! — each hop executes the reduce function and self-routes onward.
//!
//! Segments are stored in a fixed inline array ([`SegVec`]) rather than a
//! `Vec`: the cap is [`MAX_SEGMENTS`] = 16 anyway (one wire byte), and the
//! header is cloned on every fan-out/retransmit — inline storage makes
//! that clone a `memcpy` with no heap traffic on the DES hot path.

use std::ops::{Deref, DerefMut};

use anyhow::{bail, Result};

use super::frame::DeviceIp;
use crate::util::bytes::{Reader, Writer};

/// "No function, just forward/deliver."
pub const FUNC_NONE: u16 = 0;

/// One segment: where to go, and what to run there.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Segment {
    pub node: DeviceIp,
    /// Function selector executed at this hop; `FUNC_NONE` = plain deliver.
    /// For collective packets the function is implied by the instruction,
    /// so this field doubles as a per-hop argument (e.g. chunk index).
    pub func: u16,
}

impl Segment {
    pub fn to(node: DeviceIp) -> Self {
        Segment {
            node,
            func: FUNC_NONE,
        }
    }

    pub fn call(node: DeviceIp, func: u16) -> Self {
        Segment { node, func }
    }
}

/// Hard cap (wire field is one byte; real SROU stacks are short).
pub const MAX_SEGMENTS: usize = 16;

/// A fixed-capacity inline segment list. Derefs to `&[Segment]`, so all
/// slice reads (`iter`, indexing, `len`, `last`) work unchanged; `Copy`
/// because 16 segments is 96 bytes of plain data.
#[derive(Clone, Copy)]
pub struct SegVec {
    buf: [Segment; MAX_SEGMENTS],
    len: u8,
}

impl SegVec {
    pub fn new() -> Self {
        Self {
            buf: [Segment {
                node: DeviceIp(0),
                func: FUNC_NONE,
            }; MAX_SEGMENTS],
            len: 0,
        }
    }

    /// Append a segment. Panics past [`MAX_SEGMENTS`] (the wire cap).
    pub fn push(&mut self, seg: Segment) {
        assert!((self.len as usize) < MAX_SEGMENTS, "segment list overflow");
        self.buf[self.len as usize] = seg;
        self.len += 1;
    }

    pub fn as_slice(&self) -> &[Segment] {
        &self.buf[..self.len as usize]
    }
}

impl Default for SegVec {
    fn default() -> Self {
        Self::new()
    }
}

impl Deref for SegVec {
    type Target = [Segment];
    fn deref(&self) -> &[Segment] {
        self.as_slice()
    }
}

impl DerefMut for SegVec {
    fn deref_mut(&mut self) -> &mut [Segment] {
        let n = self.len as usize;
        &mut self.buf[..n]
    }
}

impl PartialEq for SegVec {
    fn eq(&self, other: &Self) -> bool {
        self.as_slice() == other.as_slice()
    }
}
impl Eq for SegVec {}

impl std::fmt::Debug for SegVec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        self.as_slice().fmt(f)
    }
}

impl From<Vec<Segment>> for SegVec {
    fn from(v: Vec<Segment>) -> Self {
        let mut s = SegVec::new();
        for seg in v {
            s.push(seg);
        }
        s
    }
}

impl<'a> IntoIterator for &'a SegVec {
    type Item = &'a Segment;
    type IntoIter = std::slice::Iter<'a, Segment>;
    fn into_iter(self) -> Self::IntoIter {
        self.as_slice().iter()
    }
}

/// The SROU segment stack.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct SrouHeader {
    /// Segment list in travel order: `segments[0]` is the first hop.
    /// (SRv6 stores it reversed on the wire; we keep travel order in
    /// memory and reverse in the codec to stay faithful to the RFC style.)
    pub segments: SegVec,
    /// Index of the next segment to visit. `== segments.len()` means the
    /// packet hasn't departed; 0 means final delivery done.
    pub left: u8,
}

impl SrouHeader {
    /// A direct path to one destination (degenerate single segment).
    pub fn direct(dst: DeviceIp) -> Self {
        let mut segments = SegVec::new();
        segments.push(Segment::to(dst));
        Self { segments, left: 1 }
    }

    /// A path through the given segments, ready to travel.
    pub fn through(segments: Vec<Segment>) -> Self {
        assert!(
            (1..=MAX_SEGMENTS).contains(&segments.len()),
            "segment count {} out of range",
            segments.len()
        );
        let left = segments.len() as u8;
        Self {
            segments: SegVec::from(segments),
            left,
        }
    }

    /// The segment the packet is currently travelling toward.
    pub fn current(&self) -> Option<Segment> {
        if self.left == 0 {
            return None;
        }
        self.segments
            .get(self.segments.len() - self.left as usize)
            .copied()
    }

    /// Advance the pointer after arriving at the current segment. Returns
    /// the *next* segment if any (i.e. the packet must be forwarded).
    pub fn advance(&mut self) -> Option<Segment> {
        if self.left == 0 {
            return None;
        }
        self.left -= 1;
        self.current()
    }

    /// Is the currently-targeted segment the last one?
    pub fn at_last_hop(&self) -> bool {
        self.left == 1
    }

    /// Remaining hops including the current target.
    pub fn hops_remaining(&self) -> usize {
        self.left as usize
    }

    pub fn encode(&self, w: &mut Writer) {
        w.u8(self.segments.len() as u8);
        w.u8(self.left);
        // Wire order is reversed (last segment first), SRv6-style.
        for seg in self.segments.iter().rev() {
            w.u32(seg.node.0);
            w.u16(seg.func);
        }
    }

    pub fn decode(r: &mut Reader) -> Result<SrouHeader> {
        let n = r.u8()? as usize;
        if n == 0 || n > MAX_SEGMENTS {
            bail!("bad segment count {n}");
        }
        let left = r.u8()?;
        if left as usize > n {
            bail!("segments-left {left} exceeds count {n}");
        }
        let mut segments = SegVec::new();
        segments.len = n as u8;
        for i in (0..n).rev() {
            segments.buf[i] = Segment {
                node: DeviceIp(r.u32()?),
                func: r.u16()?,
            };
        }
        Ok(SrouHeader { segments, left })
    }

    /// Encoded size in bytes.
    pub fn wire_len(&self) -> usize {
        2 + 6 * self.segments.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ip(x: u8) -> DeviceIp {
        DeviceIp::lan(x)
    }

    #[test]
    fn direct_header_travels_one_hop() {
        let mut h = SrouHeader::direct(ip(9));
        assert_eq!(h.current().unwrap().node, ip(9));
        assert!(h.at_last_hop());
        assert_eq!(h.advance(), None);
        assert_eq!(h.current(), None);
    }

    #[test]
    fn ring_traversal_order() {
        let mut h = SrouHeader::through(vec![
            Segment::call(ip(2), 1),
            Segment::call(ip(3), 2),
            Segment::call(ip(4), 3),
        ]);
        assert_eq!(h.hops_remaining(), 3);
        assert_eq!(h.current().unwrap().node, ip(2));
        assert!(!h.at_last_hop());
        let nxt = h.advance().unwrap();
        assert_eq!(nxt.node, ip(3));
        let nxt = h.advance().unwrap();
        assert_eq!(nxt.node, ip(4));
        assert!(h.at_last_hop());
        assert_eq!(h.advance(), None);
    }

    #[test]
    fn codec_round_trip_mid_flight() {
        let mut h = SrouHeader::through(vec![
            Segment::call(ip(2), 7),
            Segment::to(ip(3)),
            Segment::call(ip(4), 9),
        ]);
        h.advance(); // simulate one hop done
        let mut w = Writer::default();
        h.encode(&mut w);
        let v = w.into_vec();
        assert_eq!(v.len(), h.wire_len());
        let g = SrouHeader::decode(&mut Reader::new(&v)).unwrap();
        assert_eq!(g, h);
    }

    #[test]
    fn bad_headers_rejected() {
        // count 0
        assert!(SrouHeader::decode(&mut Reader::new(&[0, 0])).is_err());
        // left > count
        assert!(SrouHeader::decode(&mut Reader::new(&[1, 2, 0, 0, 0, 1, 0, 0])).is_err());
        // truncated segment
        assert!(SrouHeader::decode(&mut Reader::new(&[1, 1, 0, 0])).is_err());
    }

    #[test]
    fn segvec_is_inline_slice_compatible() {
        let mut s = SegVec::new();
        assert!(s.is_empty());
        for i in 0..MAX_SEGMENTS {
            s.push(Segment::call(ip(i as u8 + 1), i as u16));
        }
        assert_eq!(s.len(), MAX_SEGMENTS);
        assert_eq!(s[0].node, ip(1));
        assert_eq!(s.last().unwrap().func, (MAX_SEGMENTS - 1) as u16);
        let copy = s; // Copy, not a heap clone
        assert_eq!(copy, s);
    }

    #[test]
    #[should_panic(expected = "segment list overflow")]
    fn segvec_rejects_overflow() {
        let mut s = SegVec::new();
        for i in 0..=MAX_SEGMENTS {
            s.push(Segment::call(ip(1), i as u16));
        }
    }
}
