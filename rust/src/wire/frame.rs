//! Ethernet / IPv4 / UDP carrier framing.
//!
//! The DES charges serialization time for the *whole* frame, so the
//! overhead constants here matter for every timing result. We also provide
//! a real header codec (checksummed IPv4) because the examples serialize
//! NetDAM packets to actual bytes — the simulator is packet-structured,
//! but E7 (wire bench/tests) proves the byte format round-trips.

use anyhow::{bail, Result};

use crate::util::bytes::{Reader, Writer};

/// Ethernet: 14 B header + 4 B FCS. (Preamble+IFG are charged separately
/// by the link model as PREAMBLE_IFG below.)
pub const ETH_OVERHEAD: usize = 18;
/// 8 B preamble/SFD + 12 B minimum inter-frame gap, charged per frame.
pub const PREAMBLE_IFG: usize = 20;
pub const IPV4_HEADER: usize = 20;
pub const UDP_HEADER: usize = 8;
/// Total carrier overhead on top of the NetDAM payload bytes.
pub const WIRE_OVERHEAD: usize = ETH_OVERHEAD + PREAMBLE_IFG + IPV4_HEADER + UDP_HEADER;

/// The well-known NetDAM UDP port (SROU draft uses a configured port).
pub const NETDAM_UDP_PORT: u16 = 0xDA;

/// A NetDAM device address — an IPv4 address in the paper's deployment
/// ("IOMMU to translate Global Virtual Address to NetDAM device IP").
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct DeviceIp(pub u32);

impl DeviceIp {
    /// 10.0.0.x convenience constructor used by topology builders.
    pub fn lan(host: u8) -> Self {
        DeviceIp(0x0A00_0000 | host as u32)
    }
}

impl std::fmt::Display for DeviceIp {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let o = self.0.to_be_bytes();
        write!(f, "{}.{}.{}.{}", o[0], o[1], o[2], o[3])
    }
}

/// The IPv4 ECN field's Congestion-Experienced codepoint (RFC 3168).
pub const ECN_CE: u8 = 0b11;

/// Minimal IPv4+UDP header pair for the byte codec.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CarrierHeader {
    pub src: DeviceIp,
    pub dst: DeviceIp,
    pub udp_len: u16, // UDP header + NetDAM bytes
    /// Congestion Experienced: a switch queue over its ECN threshold
    /// marked this packet. Carried in the IPv4 TOS byte's ECN bits —
    /// the mark a DCQCN-style receiver echoes back to the sender.
    pub ecn: bool,
}

/// RFC 1071 internet checksum over `data`.
fn inet_checksum(data: &[u8]) -> u16 {
    let mut sum: u32 = 0;
    let mut chunks = data.chunks_exact(2);
    for c in &mut chunks {
        sum += u16::from_be_bytes([c[0], c[1]]) as u32;
    }
    if let [b] = chunks.remainder() {
        sum += (*b as u32) << 8;
    }
    while sum >> 16 != 0 {
        sum = (sum & 0xFFFF) + (sum >> 16);
    }
    !(sum as u16)
}

impl CarrierHeader {
    pub fn encode(&self, w: &mut Writer) {
        // IPv4 header (no options).
        let mut ip = Writer::with_capacity(IPV4_HEADER);
        ip.u8(0x45); // v4, IHL=5
        ip.u8(if self.ecn { ECN_CE } else { 0 }); // DSCP=0, ECN bits live
        ip.u16(IPV4_HEADER as u16 + self.udp_len);
        ip.u16(0); // identification
        ip.u16(0x4000); // DF
        ip.u8(64); // TTL
        ip.u8(17); // UDP
        ip.u16(0); // checksum placeholder
        ip.u32(self.src.0);
        ip.u32(self.dst.0);
        let mut bytes = ip.into_vec();
        let ck = inet_checksum(&bytes);
        bytes[10..12].copy_from_slice(&ck.to_be_bytes());
        w.bytes(&bytes);
        // UDP header.
        w.u16(NETDAM_UDP_PORT);
        w.u16(NETDAM_UDP_PORT);
        w.u16(self.udp_len);
        w.u16(0); // UDP checksum optional over IPv4
    }

    pub fn decode(r: &mut Reader) -> Result<CarrierHeader> {
        let start = r.pos();
        let vihl = r.u8()?;
        if vihl != 0x45 {
            bail!("unsupported IP version/IHL {vihl:#04x}");
        }
        let tos = r.u8()?;
        let ecn = tos & 0b11 == ECN_CE;
        let total_len = r.u16()?;
        let _id = r.u16()?;
        let _frag = r.u16()?;
        let _ttl = r.u8()?;
        let proto = r.u8()?;
        if proto != 17 {
            bail!("not UDP (proto {proto})");
        }
        let _ck = r.u16()?;
        let src = DeviceIp(r.u32()?);
        let dst = DeviceIp(r.u32()?);
        debug_assert_eq!(r.pos() - start, IPV4_HEADER);
        let sport = r.u16()?;
        let dport = r.u16()?;
        if sport != NETDAM_UDP_PORT || dport != NETDAM_UDP_PORT {
            bail!("not a NetDAM port pair ({sport},{dport})");
        }
        let udp_len = r.u16()?;
        let _udp_ck = r.u16()?;
        if total_len as usize != IPV4_HEADER + udp_len as usize {
            bail!("IP/UDP length mismatch");
        }
        Ok(CarrierHeader {
            src,
            dst,
            udp_len,
            ecn,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn carrier_round_trip() {
        let h = CarrierHeader {
            src: DeviceIp::lan(1),
            dst: DeviceIp::lan(2),
            udp_len: UDP_HEADER as u16 + 100,
            ecn: false,
        };
        let mut w = Writer::default();
        h.encode(&mut w);
        let v = w.into_vec();
        assert_eq!(v.len(), IPV4_HEADER + UDP_HEADER);
        let mut r = Reader::new(&v);
        assert_eq!(CarrierHeader::decode(&mut r).unwrap(), h);
    }

    #[test]
    fn ecn_mark_rides_the_tos_byte() {
        // The regression this guards: the emitted IPv4 header used to
        // hard-code DSCP/ECN to 0, losing the switch's CE mark.
        let h = CarrierHeader {
            src: DeviceIp::lan(1),
            dst: DeviceIp::lan(2),
            udp_len: 40,
            ecn: true,
        };
        let mut w = Writer::default();
        h.encode(&mut w);
        let v = w.into_vec();
        assert_eq!(v[1] & 0b11, ECN_CE, "CE codepoint on the wire");
        let back = CarrierHeader::decode(&mut Reader::new(&v)).unwrap();
        assert!(back.ecn, "mark survives decode");
        assert_eq!(back, h);
        // And the checksum still validates with the live TOS byte.
        assert_eq!(inet_checksum(&v[..IPV4_HEADER]), 0);
    }

    #[test]
    fn ipv4_checksum_validates() {
        let h = CarrierHeader {
            src: DeviceIp::lan(3),
            dst: DeviceIp::lan(4),
            udp_len: 50,
            ecn: false,
        };
        let mut w = Writer::default();
        h.encode(&mut w);
        let v = w.into_vec();
        // Checksum over the IPv4 header must be zero when included.
        assert_eq!(inet_checksum(&v[..IPV4_HEADER]), 0);
    }

    #[test]
    fn device_ip_display() {
        assert_eq!(DeviceIp::lan(7).to_string(), "10.0.0.7");
    }

    #[test]
    fn corrupt_carrier_rejected() {
        let h = CarrierHeader {
            src: DeviceIp::lan(1),
            dst: DeviceIp::lan(2),
            udp_len: 30,
            ecn: false,
        };
        let mut w = Writer::default();
        h.encode(&mut w);
        let mut v = w.into_vec();
        v[0] = 0x46; // IHL=6 unsupported
        assert!(CarrierHeader::decode(&mut Reader::new(&v)).is_err());
    }
}
