//! Bench `sim` — throughput of the DES core itself: the classic
//! single-heap engine vs the sharded parallel core at 1, 2, 4 and 8
//! shards, all driving the *same* fat-tree allreduce workload. Reports
//! simulated events per wallclock second and asserts the grid agrees on
//! the simulated result (the determinism contract, measured rather than
//! assumed). Writes the machine-readable artifact `BENCH_sim.json`.
//!
//! Perf observability (PR 9): the bench bin installs a counting global
//! allocator and reports **allocations per event** for every arm — the
//! number the allocation-free hot path is supposed to drive toward zero
//! — plus run metadata (host cores, total wallclock) and each core's
//! peak live-event count (classic: the engine heap's high-water mark;
//! sharded: per-shard heap peaks summed per round, maxed across rounds —
//! `Fabric::sharded_peak_live`). The strict zero-alloc *assertion*
//! lives in `rust/tests/alloc_free_hot_path.rs`; the bench reports the
//! whole-run average, which also pays one-time warmup growth.
//!
//! Set `NETDAM_BENCH_SMOKE=1` for a small workload (CI smoke). The
//! shard grid AND the scale target — a 1024-rank fat-tree ring
//! allreduce through the 8-shard core — run in **both** modes, so CI
//! can assert the 1024-rank arm completed instead of trusting that it
//! would have; smoke just shrinks the per-rank vector.
//!
//! Caveat printed with the numbers: on a single-CPU host the sharded
//! arms pay partitioning overhead without parallel speedup — the grid
//! is an honest overhead/scaling measurement, not a guaranteed win.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use netdam::comm::Fabric;
use netdam::metrics::Table;
use netdam::sim::fmt_ns;

/// Counts every heap allocation (and reallocation) in the process.
/// Frees are deliberately not counted: the hot-path contract is about
/// not *acquiring* memory per event.
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

struct ArmResult {
    label: String,
    shards: usize,
    events: u64,
    sim_ns: u64,
    wall: std::time::Duration,
    /// Heap allocations during the measured rounds (fabric build excluded).
    allocs: u64,
    /// High-water mark of live scheduled events (classic: engine heap;
    /// sharded: sum of per-shard heap peaks).
    peak_live: usize,
}

/// Drive `rounds` back-to-back allreduces on a fat-tree fabric and
/// count DES events against wallclock. `shards == 0` is the classic
/// single-heap engine.
fn run_arm(
    shards: usize,
    pods: usize,
    devs_per_leaf: usize,
    elements: usize,
    rounds: usize,
) -> ArmResult {
    let mut builder = Fabric::builder()
        .fat_tree(pods, devs_per_leaf, 2)
        .seed(0x51B3)
        .window(16)
        .timing_only(true);
    if shards > 0 {
        builder = builder.with_shards(shards).shard_threads(0);
    }
    let mut f = builder.build().expect("fabric");
    let comm = f.communicator(elements as u64 * 4).expect("communicator");
    let wall = std::time::Instant::now();
    let t0 = f.now();
    let allocs0 = ALLOCS.load(Ordering::Relaxed);
    for _ in 0..rounds {
        let h = comm.iallreduce(&mut f, elements).expect("submit");
        let out = f.wait(h).expect("wait");
        assert!(out.complete(), "allreduce stopped short");
    }
    let allocs = ALLOCS.load(Ordering::Relaxed) - allocs0;
    let sim_ns = f.now() - t0;
    let wall = wall.elapsed();
    let (events, peak_live) = if shards > 0 {
        (f.sharded_events(), f.sharded_peak_live() as usize)
    } else {
        let eng = f.raw_parts().1;
        (eng.events_processed(), eng.peak_live())
    };
    ArmResult {
        label: if shards > 0 {
            format!("sharded({shards})")
        } else {
            "classic".to_string()
        },
        shards,
        events,
        sim_ns,
        wall,
        allocs,
        peak_live,
    }
}

fn main() {
    let wall_total = std::time::Instant::now();
    let smoke = std::env::var("NETDAM_BENCH_SMOKE").is_ok();
    let (pods, devs_per_leaf, elements, rounds) = if smoke {
        (2usize, 4usize, 8 * 512usize, 1usize)
    } else {
        (4, 8, 1 << 16, 3)
    };
    let ranks = pods * devs_per_leaf;
    let host_cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    println!(
        "# sim — DES core throughput: classic vs sharded, {ranks}-rank fat-tree allreduce \
         ({elements} x f32, {rounds} round(s))\n"
    );
    println!(
        "host parallelism: {host_cores} (single-CPU hosts measure sharding overhead, not speedup)\n"
    );

    // Every arm this bench is contracted to run. The in-bench count
    // check below plus the CI assertion on BENCH_sim.json make a
    // silently skipped arm a hard failure, not a quieter report.
    let grid: [usize; 5] = [0, 1, 2, 4, 8];
    let expected_rows = grid.len() + 1; // shard grid + the 1024-rank arm

    let mut table = Table::new(&[
        "core",
        "events",
        "sim time",
        "wallclock",
        "events/sec",
        "allocs/event",
    ]);
    let mut json_rows: Vec<String> = Vec::new();
    let mut arms: Vec<ArmResult> = Vec::new();
    for shards in grid {
        let r = run_arm(shards, pods, devs_per_leaf, elements, rounds);
        let eps = r.events as f64 / r.wall.as_secs_f64().max(1e-9);
        let ape = r.allocs as f64 / (r.events as f64).max(1.0);
        table.row(&[
            r.label.clone(),
            r.events.to_string(),
            fmt_ns(r.sim_ns),
            format!("{:.2?}", r.wall),
            format!("{eps:.0}"),
            format!("{ape:.4}"),
        ]);
        json_rows.push(format!(
            "    {{\"workload\": \"fat_tree_allreduce\", \"core\": \"{}\", \"shards\": {}, \
             \"ranks\": {ranks}, \"elements\": {elements}, \"rounds\": {rounds}, \
             \"events\": {}, \"sim_elapsed_ns\": {}, \"wall_ms\": {:.3}, \
             \"events_per_sec\": {eps:.0}, \"allocs\": {}, \"allocs_per_event\": {ape:.4}, \
             \"peak_live_events\": {}}}",
            r.label,
            r.shards,
            r.events,
            r.sim_ns,
            r.wall.as_secs_f64() * 1e3,
            r.allocs,
            r.peak_live,
        ));
        arms.push(r);
    }
    println!("{}", table.render());

    // Determinism, measured: every sharded arm must land on the same
    // simulated time AND the same event count (the integration tests
    // prove this at report granularity; here it holds for the whole
    // grid). The classic engine counts scheduler events rather than
    // network events, so report its sim-time delta instead of asserting.
    for w in arms[1..].windows(2) {
        assert_eq!(
            (w[0].sim_ns, w[0].events),
            (w[1].sim_ns, w[1].events),
            "{} and {} disagree on the simulated result",
            w[0].label,
            w[1].label
        );
    }
    println!(
        "grid agreement: sharded arms all landed on sim time {} / {} events ✓ \
         (classic: {}, peak {} live events)\n",
        fmt_ns(arms[1].sim_ns),
        arms[1].events,
        fmt_ns(arms[0].sim_ns),
        arms[0].peak_live
    );

    // The scale target: 1024 ranks through the 8-shard core. Runs in
    // smoke mode too (with a shorter per-rank vector) so CI exercises
    // the full fabric size on every push.
    {
        let scale_ranks = 1024usize;
        let scale_elements = if smoke { scale_ranks } else { 2 * scale_ranks };
        println!(
            "## 1024-rank fat-tree ring allreduce ({scale_elements} x f32, 8-shard core, \
             timing-only)\n"
        );
        let wall = std::time::Instant::now();
        let allocs0 = ALLOCS.load(Ordering::Relaxed);
        let mut f = Fabric::builder()
            .fat_tree(32, 32, 8)
            .timing_only(true)
            .seed(0x400)
            .with_shards(8)
            .build()
            .expect("1024-rank fabric");
        assert_eq!(f.ranks(), scale_ranks);
        let comm = f
            .communicator(scale_elements as u64 * 4)
            .expect("communicator");
        let h = comm.iallreduce(&mut f, scale_elements).expect("submit");
        let out = f.wait(h).expect("wait");
        assert!(out.complete(), "1024-rank allreduce stopped short");
        let allocs = ALLOCS.load(Ordering::Relaxed) - allocs0;
        let events = f.sharded_events();
        let peak_live = f.sharded_peak_live();
        let eps = events as f64 / wall.elapsed().as_secs_f64().max(1e-9);
        let ape = allocs as f64 / (events as f64).max(1.0);
        println!(
            "completed: {} ops, sim {}, wallclock {:.2?}, {:.0} events/sec, \
             {:.4} allocs/event (incl. fabric build)\n",
            out.ops,
            fmt_ns(out.elapsed_ns()),
            wall.elapsed(),
            eps,
            ape
        );
        json_rows.push(format!(
            "    {{\"workload\": \"fat_tree_allreduce_1024\", \"core\": \"sharded(8)\", \
             \"shards\": 8, \"ranks\": 1024, \"elements\": {scale_elements}, \"rounds\": 1, \
             \"events\": {events}, \"sim_elapsed_ns\": {}, \"wall_ms\": {:.3}, \
             \"events_per_sec\": {eps:.0}, \"allocs\": {allocs}, \
             \"allocs_per_event\": {ape:.4}, \"peak_live_events\": {peak_live}}}",
            out.elapsed_ns(),
            wall.elapsed().as_secs_f64() * 1e3,
        ));
    }

    assert_eq!(
        json_rows.len(),
        expected_rows,
        "a grid arm was silently skipped: {}/{expected_rows} rows",
        json_rows.len()
    );

    let json = format!(
        "{{\n  \"bench\": \"sim\",\n  \"smoke\": {smoke},\n  \"meta\": {{\"host_cores\": \
         {host_cores}, \"total_wall_ms\": {:.3}, \"expected_rows\": {expected_rows}}},\n  \
         \"results\": [\n{}\n  ]\n}}\n",
        wall_total.elapsed().as_secs_f64() * 1e3,
        json_rows.join(",\n")
    );
    std::fs::write("BENCH_sim.json", &json).expect("write BENCH_sim.json");
    println!("wrote BENCH_sim.json ({} rows)", json_rows.len());
    println!("bench wallclock: {:.2?}", wall_total.elapsed());
}
