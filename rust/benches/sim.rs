//! Bench `sim` — throughput of the DES core itself: the classic
//! single-heap engine vs the sharded parallel core at 1, 2, 4 and 8
//! shards, all driving the *same* fat-tree allreduce workload. Reports
//! simulated events per wallclock second and asserts the grid agrees on
//! the simulated result (the determinism contract, measured rather than
//! assumed). Writes the machine-readable artifact `BENCH_sim.json`.
//!
//! Set `NETDAM_BENCH_SMOKE=1` for a small workload (CI smoke; the full
//! shard grid still runs). The full run adds the scale target: a
//! 1024-rank fat-tree ring allreduce through the 8-shard core.
//!
//! Caveat printed with the numbers: on a single-CPU host the sharded
//! arms pay partitioning overhead without parallel speedup — the grid
//! is an honest overhead/scaling measurement, not a guaranteed win.

use netdam::comm::Fabric;
use netdam::metrics::Table;
use netdam::sim::fmt_ns;

struct ArmResult {
    label: String,
    shards: usize,
    events: u64,
    sim_ns: u64,
    wall: std::time::Duration,
}

/// Drive `rounds` back-to-back allreduces on a fat-tree fabric and
/// count DES events against wallclock. `shards == 0` is the classic
/// single-heap engine.
fn run_arm(
    shards: usize,
    pods: usize,
    devs_per_leaf: usize,
    elements: usize,
    rounds: usize,
) -> ArmResult {
    let mut builder = Fabric::builder()
        .fat_tree(pods, devs_per_leaf, 2)
        .seed(0x51B3)
        .window(16)
        .timing_only(true);
    if shards > 0 {
        builder = builder.with_shards(shards).shard_threads(0);
    }
    let mut f = builder.build().expect("fabric");
    let comm = f.communicator(elements as u64 * 4).expect("communicator");
    let wall = std::time::Instant::now();
    let t0 = f.now();
    for _ in 0..rounds {
        let h = comm.iallreduce(&mut f, elements).expect("submit");
        let out = f.wait(h).expect("wait");
        assert!(out.complete(), "allreduce stopped short");
    }
    let sim_ns = f.now() - t0;
    let wall = wall.elapsed();
    let events = if shards > 0 {
        f.sharded_events()
    } else {
        f.raw_parts().1.events_processed()
    };
    ArmResult {
        label: if shards > 0 {
            format!("sharded({shards})")
        } else {
            "classic".to_string()
        },
        shards,
        events,
        sim_ns,
        wall,
    }
}

fn main() {
    let wall_total = std::time::Instant::now();
    let smoke = std::env::var("NETDAM_BENCH_SMOKE").is_ok();
    let (pods, devs_per_leaf, elements, rounds) = if smoke {
        (2usize, 4usize, 8 * 512usize, 1usize)
    } else {
        (4, 8, 1 << 16, 3)
    };
    let ranks = pods * devs_per_leaf;
    println!(
        "# sim — DES core throughput: classic vs sharded, {ranks}-rank fat-tree allreduce \
         ({elements} x f32, {rounds} round(s))\n"
    );
    println!(
        "host parallelism: {} (single-CPU hosts measure sharding overhead, not speedup)\n",
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    );

    let mut table = Table::new(&["core", "events", "sim time", "wallclock", "events/sec"]);
    let mut json_rows: Vec<String> = Vec::new();
    let mut arms: Vec<ArmResult> = Vec::new();
    for shards in [0usize, 1, 2, 4, 8] {
        let r = run_arm(shards, pods, devs_per_leaf, elements, rounds);
        let eps = r.events as f64 / r.wall.as_secs_f64().max(1e-9);
        table.row(&[
            r.label.clone(),
            r.events.to_string(),
            fmt_ns(r.sim_ns),
            format!("{:.2?}", r.wall),
            format!("{eps:.0}"),
        ]);
        json_rows.push(format!(
            "    {{\"workload\": \"fat_tree_allreduce\", \"core\": \"{}\", \"shards\": {}, \
             \"ranks\": {ranks}, \"elements\": {elements}, \"rounds\": {rounds}, \
             \"events\": {}, \"sim_elapsed_ns\": {}, \"wall_ms\": {:.3}, \
             \"events_per_sec\": {eps:.0}}}",
            r.label,
            r.shards,
            r.events,
            r.sim_ns,
            r.wall.as_secs_f64() * 1e3,
        ));
        arms.push(r);
    }
    println!("{}", table.render());

    // Determinism, measured: every sharded arm must land on the same
    // simulated time AND the same event count (the integration tests
    // prove this at report granularity; here it holds for the whole
    // grid). The classic engine counts scheduler closures rather than
    // network events, so report its sim-time delta instead of asserting.
    for w in arms[1..].windows(2) {
        assert_eq!(
            (w[0].sim_ns, w[0].events),
            (w[1].sim_ns, w[1].events),
            "{} and {} disagree on the simulated result",
            w[0].label,
            w[1].label
        );
    }
    println!(
        "grid agreement: sharded arms all landed on sim time {} / {} events ✓ \
         (classic: {})\n",
        fmt_ns(arms[1].sim_ns),
        arms[1].events,
        fmt_ns(arms[0].sim_ns)
    );

    // The scale target (full mode): 1024 ranks through the 8-shard core.
    if !smoke {
        println!("## 1024-rank fat-tree ring allreduce (8-shard core, timing-only)\n");
        let scale_ranks = 1024usize;
        let scale_elements = 2 * scale_ranks;
        let wall = std::time::Instant::now();
        let mut f = Fabric::builder()
            .fat_tree(32, 32, 8)
            .timing_only(true)
            .seed(0x400)
            .with_shards(8)
            .build()
            .expect("1024-rank fabric");
        assert_eq!(f.ranks(), scale_ranks);
        let comm = f
            .communicator(scale_elements as u64 * 4)
            .expect("communicator");
        let h = comm.iallreduce(&mut f, scale_elements).expect("submit");
        let out = f.wait(h).expect("wait");
        assert!(out.complete(), "1024-rank allreduce stopped short");
        let eps = f.sharded_events() as f64 / wall.elapsed().as_secs_f64().max(1e-9);
        println!(
            "completed: {} ops, sim {}, wallclock {:.2?}, {:.0} events/sec\n",
            out.ops,
            fmt_ns(out.elapsed_ns()),
            wall.elapsed(),
            eps
        );
        json_rows.push(format!(
            "    {{\"workload\": \"fat_tree_allreduce_1024\", \"core\": \"sharded(8)\", \
             \"shards\": 8, \"ranks\": 1024, \"elements\": {scale_elements}, \"rounds\": 1, \
             \"events\": {}, \"sim_elapsed_ns\": {}, \"wall_ms\": {:.3}, \
             \"events_per_sec\": {eps:.0}}}",
            f.sharded_events(),
            out.elapsed_ns(),
            wall.elapsed().as_secs_f64() * 1e3,
        ));
    }

    let json = format!(
        "{{\n  \"bench\": \"sim\",\n  \"smoke\": {smoke},\n  \"results\": [\n{}\n  ]\n}}\n",
        json_rows.join(",\n")
    );
    std::fs::write("BENCH_sim.json", &json).expect("write BENCH_sim.json");
    println!("wrote BENCH_sim.json ({} rows)", json_rows.len());
    println!("bench wallclock: {:.2?}", wall_total.elapsed());
}
