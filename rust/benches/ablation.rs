//! Ablation bench A1 — the design knobs DESIGN.md calls out, on the E2
//! workload:
//!
//! * SIMD block width (the 2048-lane claim vs narrower packets);
//! * window (self-clocking depth);
//! * fused all-gather vs reduce-scatter only;
//! * reliability machinery on a lossless fabric (overhead check);
//! * loss tolerance: idempotent retransmit under 1% loss.

use netdam::collectives::{run_ring_allreduce, RingSpec};
use netdam::device::DeviceConfig;
use netdam::metrics::Table;
use netdam::net::{Cluster, LinkConfig, Switch};
use netdam::sim::{fmt_ns, Engine};
use netdam::wire::DeviceIp;

fn cluster(seed: u64, loss_p: f64) -> (Cluster, Vec<netdam::net::NodeId>) {
    let mut cl = Cluster::new(seed);
    let sw = cl.add_switch(Switch::tor(None));
    let mut devices = Vec::new();
    for i in 0..4u8 {
        let d = cl.add_device(DeviceConfig::paper_default(DeviceIp::lan(1 + i)).timing_only());
        cl.connect(sw, d, LinkConfig::dc_100g());
        devices.push(d);
    }
    cl.compute_routes();
    cl.fault.loss_p = loss_p;
    (cl, devices)
}

fn run(spec: &RingSpec, loss_p: f64) -> (u64, u64, usize) {
    let (mut cl, devices) = cluster(0xAB, loss_p);
    let mut eng: Engine<Cluster> = Engine::new();
    let out = run_ring_allreduce(&mut cl, &mut eng, &devices, spec).expect("run");
    assert_eq!(
        out.blocks_done, out.blocks,
        "incomplete run in ablation (drops: {}) — deep unreliable windows \
         can overrun the switch buffer; use reliable mode",
        cl.metrics.counter("link_drops")
    );
    (out.elapsed_ns, out.retransmits, out.blocks)
}

fn main() {
    let wall = std::time::Instant::now();
    let elements = 1 << 22;
    println!("# A1 — ablations on the {elements}-element allreduce\n");

    println!("## SIMD lanes per packet (9000B jumbo = 2048 lanes)\n");
    let mut t = Table::new(&["lanes/packet", "time", "slowdown vs 2048"]);
    let (base, ..) = run(
        &RingSpec {
            elements,
            lanes: 2048,
            window: 32,
            ..Default::default()
        },
        0.0,
    );
    for lanes in [256usize, 512, 1024, 2048] {
        let (ns, ..) = run(
            &RingSpec {
                elements,
                lanes,
                window: 32,
                ..Default::default()
            },
            0.0,
        );
        t.row(&[
            lanes.to_string(),
            fmt_ns(ns),
            format!("{:.2}x", ns as f64 / base as f64),
        ]);
    }
    println!("{}", t.render());

    println!("## window (outstanding blocks per rank)\n");
    // Beyond ~55 blocks the initial burst (window x 9 KB) overruns the
    // 500 KB switch egress buffer: deeper windows need reliable mode.
    // That interaction is itself a finding — shown as the last two rows.
    let mut t = Table::new(&["window", "time", "retransmits"]);
    for window in [1usize, 2, 4, 8, 16, 32] {
        let (ns, retx, _) = run(
            &RingSpec {
                elements,
                window,
                ..Default::default()
            },
            0.0,
        );
        t.row(&[window.to_string(), fmt_ns(ns), retx.to_string()]);
    }
    for window in [64usize, 128] {
        let (ns, retx, _) = run(
            &RingSpec {
                elements,
                window,
                reliable: true,
                ..Default::default()
            },
            0.0,
        );
        t.row(&[
            format!("{window} (reliable)"),
            fmt_ns(ns),
            retx.to_string(),
        ]);
    }
    println!("{}", t.render());

    println!("## fused all-gather vs reduce-scatter only\n");
    let mut t = Table::new(&["mode", "time", "note"]);
    for (fused, label, note) in [
        (true, "fused allreduce", "full §3 path"),
        (false, "reduce-scatter only", "≈ half the volume"),
    ] {
        let (ns, ..) = run(
            &RingSpec {
                elements,
                window: 32,
                fused,
                ..Default::default()
            },
            0.0,
        );
        t.row(&[label.to_string(), fmt_ns(ns), note.to_string()]);
    }
    println!("{}", t.render());

    println!("## reliability machinery (lossless vs 1% loss)\n");
    let mut t = Table::new(&["arm", "time", "retransmits"]);
    for (reliable, loss, label) in [
        (false, 0.0, "unreliable, lossless"),
        (true, 0.0, "reliable, lossless (overhead)"),
        (true, 0.01, "reliable, 1% loss (idempotent retry)"),
    ] {
        let (ns, retx, _) = run(
            &RingSpec {
                elements: 1 << 20, // smaller: lossy runs retransmit
                window: 16,
                reliable,
                ..Default::default()
            },
            loss,
        );
        t.row(&[label.to_string(), fmt_ns(ns), retx.to_string()]);
    }
    println!("{}", t.render());
    println!("bench wallclock: {:.2?}", wall.elapsed());
}
