//! Bench E6 — the ALU claim of §2.2/§3.1: one NetDAM instruction covers
//! 2048 × f32 lanes where AVX-512 covers 32.
//!
//! Three views:
//! * the *timing model* (what the DES charges): NetDAM ALU array vs an
//!   AVX-512 host core, per instruction;
//! * measured host throughput of the native backend (the DES hot path);
//! * the compiled Pallas artifact through PJRT (the compute plane),
//!   including per-call overhead amortization.

use netdam::alu::{AluBackend, AluCostModel, NativeAlu};
use netdam::isa::SimdOp;
use netdam::metrics::Table;
use netdam::runtime::{XlaAlu, ALU_CHUNK};
use netdam::util::Xoshiro256;

fn main() {
    let wall = std::time::Instant::now();
    println!("# E6 — SIMD ALU: 2048-lane in-memory instruction (paper §2.2)\n");

    // --- the cost model the simulator charges --------------------------
    let nd = AluCostModel::paper_default();
    let host = AluCostModel::avx512_host();
    let mut t = Table::new(&["block", "NetDAM ALU ns", "AVX-512 core ns", "ratio"]);
    for lanes in [2048usize, 8192, 65536, 1 << 20] {
        let a = nd.exec_ns(lanes);
        let b = host.exec_ns(lanes);
        t.row(&[
            format!("{lanes} x f32"),
            a.to_string(),
            b.to_string(),
            format!("{:.1}x", b as f64 / a as f64),
        ]);
    }
    println!("## modeled instruction latency\n\n{}", t.render());

    // --- native backend (DES hot path) ---------------------------------
    let mut rng = Xoshiro256::seed_from(6);
    let n = 1 << 22; // 16 MiB of lanes
    let a = rng.f32_vec(n, -10.0, 10.0);
    let b = rng.f32_vec(n, -10.0, 10.0);
    let mut t = Table::new(&["op", "native GB/s", "ns/2048-block"]);
    for op in SimdOp::ALL {
        let mut acc = a.clone();
        let t0 = std::time::Instant::now();
        NativeAlu::new().apply(op, &mut acc, &b);
        let dt = t0.elapsed();
        let gbs = (n as f64 * 4.0 * 2.0) / dt.as_nanos() as f64; // r+w streams
        t.row(&[
            op.name().to_string(),
            format!("{gbs:.1}"),
            format!("{:.0}", dt.as_nanos() as f64 / (n / 2048) as f64),
        ]);
        std::hint::black_box(&acc);
    }
    println!("## native backend throughput ({n} lanes)\n\n{}", t.render());

    // --- the Pallas/PJRT compute plane ----------------------------------
    match XlaAlu::open_default() {
        Ok(mut xla) => {
            let mut t = Table::new(&["lanes per call", "xla-pallas GB/s", "call overhead amortized"]);
            for total in [ALU_CHUNK, 8 * ALU_CHUNK, 32 * ALU_CHUNK] {
                let a2 = &a[..total];
                let b2 = &b[..total];
                // warm (compile once)
                let mut acc = a2.to_vec();
                xla.apply(SimdOp::Add, &mut acc, b2);
                let reps = 5;
                let t0 = std::time::Instant::now();
                for _ in 0..reps {
                    let mut acc = a2.to_vec();
                    xla.apply(SimdOp::Add, &mut acc, b2);
                    std::hint::black_box(&acc);
                }
                let dt = t0.elapsed() / reps;
                let gbs = (total as f64 * 4.0 * 2.0) / dt.as_nanos() as f64;
                t.row(&[
                    total.to_string(),
                    format!("{gbs:.2}"),
                    format!("{:.1} us/call", dt.as_micros() as f64 / (total / ALU_CHUNK) as f64),
                ]);
            }
            println!("## compiled Pallas kernel via PJRT (add)\n\n{}", t.render());
            println!("note: interpret-mode Pallas on CPU measures the *integration*, not TPU perf;");
            println!("TPU perf is estimated from VMEM/BlockSpec structure in DESIGN.md §Perf.");
        }
        Err(e) => println!("(xla artifacts unavailable: {e}; run `make artifacts`)"),
    }
    println!("\nbench wallclock: {:.2?}", wall.elapsed());
}
