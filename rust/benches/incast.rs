//! Bench E3 + closed-loop congestion control (PR 8).
//!
//! Part 1 regenerates the §2.5 incast-avoidance comparison: direct
//! many-to-one writes vs block-interleaved pool + paced READ pull.
//!
//! Part 2 is the DCQCN A/B grid: at fan-in {8, 32, 128} the same write
//! storm runs unpaced, with the best static per-sender budget from a
//! grid (the operator's oracle), and with the session's closed-loop
//! DCQCN — goodput, p50/p99 completion latency, and Jain fairness per
//! arm land in `BENCH_incast.json` so the perf trajectory is tracked
//! across PRs. Set `NETDAM_BENCH_SMOKE=1` for the CI smoke (single
//! small fan-in, two-point grid).

use netdam::coordinator::{run_e3, run_incast_cc, ArmStats, E3Config, IncastCcConfig};

fn json_row(fanin: usize, s: &ArmStats) -> String {
    format!(
        "    {{\"arm\": \"{}\", \"fanin\": {}, \"goodput_gbps\": {:.3}, \
         \"lat_p50_ns\": {}, \"lat_p99_ns\": {}, \"jain\": {:.4}, \
         \"link_drops\": {}, \"retransmits\": {}, \"cnps\": {}, \
         \"delivered_fraction\": {:.4}, \"elapsed_ns\": {}}}",
        s.label,
        fanin,
        s.goodput_gbps,
        s.lat_p50_ns,
        s.lat_p99_ns,
        s.jain,
        s.link_drops,
        s.retransmits,
        s.cnps,
        s.delivered_fraction,
        s.elapsed_ns
    )
}

fn main() {
    let wall = std::time::Instant::now();
    let smoke = std::env::var("NETDAM_BENCH_SMOKE").is_ok();

    println!("# E3 — incast avoidance via the interleaved pool (paper §2.5)\n");
    let pool_senders: &[usize] = if smoke { &[4] } else { &[2, 4, 8] };
    for &senders in pool_senders {
        let cfg = E3Config {
            senders,
            devices: 4,
            bytes_per_sender: if smoke { 512 << 10 } else { 2 << 20 },
            pull_fraction: 0.92,
            seed: 0xE3,
        };
        println!("## {senders} senders x {} KiB\n", cfg.bytes_per_sender >> 10);
        let r = run_e3(&cfg).expect("e3");
        println!("{}", r.table.render());
        println!(
            "incast penalty: {:.2}x slower than interleaved scatter; drops {} vs {}\n",
            r.direct_ns as f64 / r.pool_scatter_ns.max(1) as f64,
            r.direct_drops,
            r.pool_drops
        );
    }

    println!("# closed-loop CC — unpaced vs best-static vs DCQCN\n");
    let fanins: &[usize] = if smoke { &[8] } else { &[8, 32, 128] };
    let grid: Vec<f64> = if smoke {
        vec![5.0, 12.0]
    } else {
        vec![2.0, 5.0, 10.0, 25.0]
    };
    let mut json_rows: Vec<String> = Vec::new();
    for &fanin in fanins {
        let cfg = IncastCcConfig {
            fanin,
            blocks_per_sender: if smoke { 24 } else { 64 },
            window: 16,
            seed: 0x1CA5,
            static_grid_gbps: grid.clone(),
        };
        let r = run_incast_cc(&cfg).expect("incast cc");
        println!("## fan-in {fanin}\n\n{}", r.table.render());
        println!(
            "dcqcn vs best static ({}): goodput {:.2}x, p99 {:.2}x of unpaced\n",
            r.best_static.label,
            r.dcqcn.goodput_gbps / r.best_static.goodput_gbps.max(1e-9),
            r.dcqcn.lat_p99_ns as f64 / r.unpaced.lat_p99_ns.max(1) as f64,
        );
        json_rows.push(json_row(fanin, &r.unpaced));
        for s in &r.statics {
            json_rows.push(json_row(fanin, s));
        }
        let mut best = r.best_static.clone();
        best.label = format!("best-static ({})", best.label);
        json_rows.push(json_row(fanin, &best));
        json_rows.push(json_row(fanin, &r.dcqcn));
    }

    let json = format!(
        "{{\n  \"bench\": \"incast\",\n  \"smoke\": {smoke},\n  \"results\": [\n{}\n  ]\n}}\n",
        json_rows.join(",\n")
    );
    std::fs::write("BENCH_incast.json", &json).expect("write BENCH_incast.json");
    println!("wrote BENCH_incast.json ({} rows)", json_rows.len());
    println!("bench wallclock: {:.2?}", wall.elapsed());
}
