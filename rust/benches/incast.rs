//! Bench E3 — regenerates the §2.5 incast-avoidance comparison: direct
//! many-to-one writes vs block-interleaved pool + paced READ pull.

use netdam::coordinator::{run_e3, E3Config};

fn main() {
    println!("# E3 — incast avoidance via the interleaved pool (paper §2.5)\n");
    let wall = std::time::Instant::now();
    for senders in [2usize, 4, 8] {
        let cfg = E3Config {
            senders,
            devices: 4,
            bytes_per_sender: 2 << 20,
            pull_fraction: 0.92,
            seed: 0xE3,
        };
        println!("## {senders} senders x 2 MiB\n");
        let r = run_e3(&cfg).expect("e3");
        println!("{}", r.table.render());
        println!(
            "incast penalty: {:.2}x slower than interleaved scatter; drops {} vs {}\n",
            r.direct_ns as f64 / r.pool_scatter_ns.max(1) as f64,
            r.direct_drops,
            r.pool_drops
        );
    }
    println!("bench wallclock: {:.2?}", wall.elapsed());
}
