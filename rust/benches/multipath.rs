//! Bench E4 — regenerates the §2.3 multipath claim: SROU source-routed
//! spraying vs classic per-flow ECMP under elephant collisions.

use netdam::coordinator::{run_e4, E4Config};

fn main() {
    println!("# E4 — SROU multipath vs ECMP (paper §2.3)\n");
    let wall = std::time::Instant::now();
    for mb in [1usize, 4, 16] {
        let cfg = E4Config {
            devs_per_leaf: 2,
            bytes_per_flow: mb << 20,
            seed: 0xE4,
        };
        println!("## 2 elephant flows x {mb} MiB across 2 spines\n");
        let (results, table) = run_e4(&cfg).expect("e4");
        println!("{}", table.render());
        let ecmp = &results[0];
        let spray = &results[1];
        println!(
            "SROU spray speedup: {:.2}x (collision halves ECMP bandwidth)\n",
            ecmp.completion_ns as f64 / spray.completion_ns as f64
        );
    }
    println!("bench wallclock: {:.2?}", wall.elapsed());
}
