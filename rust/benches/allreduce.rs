//! Bench E2 — regenerates the §3.3 allreduce table (native MPI 2.8 s /
//! ring 2.1 s / NetDAM ≈0.4 s at 2 GiB).
//!
//! Default sweep runs up to 2^24 elements (64 MiB). Set
//! `NETDAM_PAPER_SCALE=1` to run the full 536,870,912-float vector
//! (timing-only payloads; several minutes of wallclock).

use netdam::coordinator::{run_e2, E2Config};
use netdam::sim::fmt_ns;

fn main() {
    println!("# E2 — 4-node MPI allreduce (paper §3.3)\n");
    let wall = std::time::Instant::now();
    let paper = std::env::var("NETDAM_PAPER_SCALE").is_ok();
    let sizes: Vec<usize> = if paper {
        vec![536_870_912]
    } else {
        vec![1 << 20, 1 << 22, 1 << 24]
    };
    for elements in sizes {
        let cfg = E2Config {
            elements,
            ranks: 4,
            timing_only: true,
            window: 32,
            seed: 0xE2,
            with_baselines: true,
        };
        println!(
            "## {} x f32 ({:.0} MiB)\n",
            elements,
            elements as f64 * 4.0 / (1 << 20) as f64
        );
        let r = run_e2(&cfg).expect("e2");
        println!("{}", r.table.render());
        println!(
            "speedups: {:.2}x vs ring (paper 5.3x), {:.2}x vs native (paper 7x); floor ratio {:.2}x\n",
            r.ring_roce_ns as f64 / r.netdam_ns as f64,
            r.mpi_native_ns as f64 / r.netdam_ns as f64,
            r.netdam_ns as f64 / r.line_rate_floor_ns as f64,
        );
        if paper {
            println!(
                "paper scale: NetDAM {} vs paper's ~400 ms initial measurement",
                fmt_ns(r.netdam_ns)
            );
        }
    }
    println!("bench wallclock: {:.2?}", wall.elapsed());
}
