//! Bench E2 — regenerates the §3.3 allreduce table (native MPI 2.8 s /
//! ring 2.1 s / NetDAM ≈0.4 s at 2 GiB), extended to the full collective
//! menu riding the shared `collectives::driver` (every NetDAM algorithm
//! now executes as device-run packet programs).
//!
//! Default sweep runs up to 2^24 elements (64 MiB), every algorithm on
//! the same grid, and writes the machine-readable artifact
//! `BENCH_allreduce.json` (per-algo, per-size bus-bandwidth numbers) so
//! the perf trajectory is tracked across PRs. Set `NETDAM_BENCH_SMOKE=1`
//! for a single tiny size (CI smoke); `NETDAM_PAPER_SCALE=1` runs the
//! full 536,870,912-float vector on the classic paper triple
//! (timing-only payloads; several minutes of wallclock).

use netdam::collectives::{run_collective, AlgoKind, RunOpts};
use netdam::comm::{buckets_total_elems, plan_buckets, Fabric};
use netdam::coordinator::{run_e2, E2Config};
use netdam::metrics::Table;
use netdam::sim::fmt_ns;

fn main() {
    println!("# E2 — 4-node MPI allreduce (paper §3.3)\n");
    let wall = std::time::Instant::now();
    let paper = std::env::var("NETDAM_PAPER_SCALE").is_ok();
    let smoke = std::env::var("NETDAM_BENCH_SMOKE").is_ok();
    let ranks = 4usize;

    if paper {
        let cfg = E2Config {
            elements: 536_870_912,
            ranks,
            timing_only: true,
            window: 32,
            seed: 0xE2,
            with_baselines: true,
            ..Default::default()
        };
        let r = run_e2(&cfg).expect("e2");
        println!("## 536870912 x f32 (2048 MiB)\n\n{}", r.table.render());
        println!(
            "paper scale: NetDAM {} vs paper's ~400 ms initial measurement",
            fmt_ns(r.netdam_ns)
        );
        println!("\nbench wallclock: {:.2?}", wall.elapsed());
        return;
    }

    let sizes: &[usize] = if smoke {
        &[1 << 16]
    } else {
        &[1 << 20, 1 << 22, 1 << 24]
    };
    let mut json_rows: Vec<String> = Vec::new();
    for &elements in sizes {
        println!(
            "## {} x f32 ({:.0} MiB), {} ranks — full algorithm menu\n",
            elements,
            elements as f64 * 4.0 / (1 << 20) as f64,
            ranks
        );
        let mut table = Table::new(&["algorithm", "time", "bus bw (Gbit/s)", "retransmits"]);
        let mut netdam_ns = 0;
        let mut ring_ns = 0;
        let mut native_ns = 0;
        for kind in AlgoKind::ALL {
            let opts = RunOpts {
                elements,
                ranks,
                seed: 0xE2,
                window: 32,
                timing_only: true,
                ..Default::default()
            };
            let r = run_collective(kind, &opts).expect("collective run");
            match kind {
                AlgoKind::NetdamRing => netdam_ns = r.elapsed_ns,
                AlgoKind::RingRoce => ring_ns = r.elapsed_ns,
                AlgoKind::MpiNative => native_ns = r.elapsed_ns,
                _ => {}
            }
            let frac = kind.bw_fraction(ranks);
            let bus_bw = r.bus_bw_gbps(frac);
            table.row(&[
                r.algorithm.to_string(),
                fmt_ns(r.elapsed_ns),
                format!("{bus_bw:.1}"),
                r.retransmits.to_string(),
            ]);
            json_rows.push(format!(
                "    {{\"algorithm\": \"{}\", \"elements\": {}, \"ranks\": {}, \
                 \"elapsed_ns\": {}, \"bw_fraction\": {:.4}, \"bus_bw_gbps\": {:.3}, \
                 \"retransmits\": {}}}",
                r.algorithm, elements, ranks, r.elapsed_ns, frac, bus_bw, r.retransmits
            ));
        }
        println!("{}", table.render());
        let floor = netdam::coordinator::e2_allreduce::line_rate_floor_ns(ranks, elements);
        println!(
            "speedups: {:.2}x vs ring (paper 5.3x), {:.2}x vs native (paper 7x); floor ratio {:.2}x\n",
            ring_ns as f64 / netdam_ns as f64,
            native_ns as f64 / netdam_ns as f64,
            netdam_ns as f64 / floor as f64,
        );
    }
    // --- grid 2: gradient bucketing — small-tensor streams, fused vs
    // unfused, on one session-API fabric per arm. Throughput counts only
    // the real tensor bytes (padding excluded), so fusion has to win on
    // overhead, not on accounting.
    println!("## gradient bucketing: small-tensor streams (session API)\n");
    let tensor_counts: &[usize] = if smoke { &[16] } else { &[32, 128] };
    let mut table = Table::new(&[
        "tensors",
        "mode",
        "collectives",
        "time",
        "bus bw (Gbit/s)",
    ]);
    for &n_tensors in tensor_counts {
        let sizes: Vec<usize> = (0..n_tensors).map(|i| 256 + (i * 97) % 1792).collect();
        let payload_elems: usize = sizes.iter().sum();
        let mut bw_of_mode = [0.0f64; 2];
        for (arm, (mode, cap)) in [("unfused", 0usize), ("fused", ranks * 2048)]
            .into_iter()
            .enumerate()
        {
            let buckets = plan_buckets(&sizes, cap, ranks);
            let footprint = buckets_total_elems(&buckets);
            let mut fabric = Fabric::builder()
                .star(ranks)
                .seed(0xB0CE)
                .window(32)
                .timing_only(true)
                .build()
                .expect("fabric");
            let comm = fabric
                .communicator(footprint as u64 * 4)
                .expect("communicator");
            let t0 = fabric.now();
            let handles = comm
                .iallreduce_buckets(&mut fabric, &buckets)
                .expect("bucket submit");
            for h in handles {
                let o = fabric.wait(h).expect("bucket wait");
                assert!(o.complete(), "bucket stopped short");
            }
            let elapsed = fabric.now() - t0;
            let frac = 2.0 * (ranks as f64 - 1.0) / ranks as f64;
            let bus_bw = frac * payload_elems as f64 * 4.0 * 8.0 / elapsed.max(1) as f64;
            bw_of_mode[arm] = bus_bw;
            table.row(&[
                n_tensors.to_string(),
                mode.to_string(),
                buckets.len().to_string(),
                fmt_ns(elapsed),
                format!("{bus_bw:.1}"),
            ]);
            json_rows.push(format!(
                "    {{\"algorithm\": \"bucketed-allreduce\", \"mode\": \"{mode}\", \
                 \"tensors\": {n_tensors}, \"elements\": {payload_elems}, \"ranks\": {ranks}, \
                 \"elapsed_ns\": {elapsed}, \"bw_fraction\": {frac:.4}, \
                 \"bus_bw_gbps\": {bus_bw:.3}, \"retransmits\": 0}}"
            ));
        }
        println!(
            "{n_tensors} tensors: fused/unfused throughput = {:.2}x",
            bw_of_mode[1] / bw_of_mode[0].max(1e-9)
        );
    }
    println!("{}", table.render());

    // --- grid 3: in-network aggregation head-to-head — switch-reduce
    // vs the 2-level hierarchical allreduce on the same 2-pod fat-tree
    // (`for_algo` builds fat_tree(2, ranks/2, 2) for both), across
    // vector sizes, leaf fanins, and loss rates. Both are allreduces,
    // so bus bw == algo bw and the comparison is apples-to-apples.
    println!("\n## in-network aggregation: switch-reduce vs hierarchical-2level\n");
    let sr_sizes: &[usize] = if smoke {
        &[1 << 14]
    } else {
        &[1 << 18, 1 << 20, 1 << 22]
    };
    let fanins: &[usize] = if smoke { &[2] } else { &[2, 4] };
    let mut table = Table::new(&[
        "algorithm",
        "ranks",
        "fanin",
        "loss",
        "elements",
        "time",
        "algo bw (Gbit/s)",
        "retransmits",
    ]);
    for &per_leaf in fanins {
        let ranks = 2 * per_leaf;
        for &elements in sr_sizes {
            for &(loss_p, reliable) in &[(0.0f64, false), (0.01, true)] {
                let mut bw = [0.0f64; 2];
                for (arm, kind) in [AlgoKind::Hierarchical, AlgoKind::SwitchReduce]
                    .into_iter()
                    .enumerate()
                {
                    let opts = RunOpts {
                        elements,
                        ranks,
                        seed: 0xA66,
                        window: 32,
                        timing_only: true,
                        reliable,
                        loss_p,
                        ..Default::default()
                    };
                    let r = run_collective(kind, &opts).expect("collective run");
                    let algo_bw = r.algo_bw_gbps(ranks);
                    bw[arm] = algo_bw;
                    table.row(&[
                        r.algorithm.to_string(),
                        ranks.to_string(),
                        per_leaf.to_string(),
                        format!("{loss_p:.2}"),
                        elements.to_string(),
                        fmt_ns(r.elapsed_ns),
                        format!("{algo_bw:.1}"),
                        r.retransmits.to_string(),
                    ]);
                    json_rows.push(format!(
                        "    {{\"algorithm\": \"{}\", \"elements\": {}, \"ranks\": {}, \
                         \"fanin\": {}, \"loss_p\": {:.3}, \"elapsed_ns\": {}, \
                         \"bw_fraction\": {:.4}, \"bus_bw_gbps\": {:.3}, \"retransmits\": {}}}",
                        r.algorithm,
                        elements,
                        ranks,
                        per_leaf,
                        loss_p,
                        r.elapsed_ns,
                        kind.bw_fraction(ranks),
                        algo_bw,
                        r.retransmits
                    ));
                }
                println!(
                    "fanin {per_leaf}, {elements} elems, loss {loss_p:.2}: \
                     switch-reduce/hierarchical bw = {:.2}x",
                    bw[1] / bw[0].max(1e-9)
                );
            }
        }
    }
    println!("\n{}", table.render());

    let json = format!(
        "{{\n  \"bench\": \"allreduce\",\n  \"ranks\": {ranks},\n  \"smoke\": {smoke},\n  \"results\": [\n{}\n  ]\n}}\n",
        json_rows.join(",\n")
    );
    std::fs::write("BENCH_allreduce.json", &json).expect("write BENCH_allreduce.json");
    println!("wrote BENCH_allreduce.json ({} rows)", json_rows.len());
    println!("bench wallclock: {:.2?}", wall.elapsed());
}
