//! Bench — the pooled-memory data plane (§2.5/§2.6).
//!
//! Grid 1: scatter-gather read/write bandwidth through `MemClient` as the
//! pool widens (1 → 8 devices; the host link is the roofline, the grid
//! shows how well per-device windows keep the pipe full).
//! Grid 2: the E3 incast contrast — N senders into one device (drops,
//! retransmit storm) vs the same bytes interleaved over the pool and
//! pulled back with paced READs, all through controller-programmed
//! IOMMUs.
//!
//! Writes the machine-readable artifact `BENCH_mempool.json`. Set
//! `NETDAM_BENCH_SMOKE=1` for a tiny CI-sized run.

use netdam::coordinator::{run_e3, E3Config};
use netdam::mem::MemClient;
use netdam::metrics::Table;
use netdam::net::{Cluster, LinkConfig, Topology};
use netdam::pool::{InterleaveMap, SdnController};
use netdam::sim::{fmt_ns, Engine};
use netdam::wire::DeviceIp;

fn gbps(bytes: usize, ns: u64) -> f64 {
    bytes as f64 * 8.0 / ns.max(1) as f64
}

fn main() {
    let wall = std::time::Instant::now();
    let smoke = std::env::var("NETDAM_BENCH_SMOKE").is_ok();
    println!("# Pooled-memory grid (controller -> IOMMU -> MemClient)\n");

    let device_counts: &[usize] = if smoke { &[2] } else { &[1, 2, 4, 8] };
    let bytes = if smoke { 256 << 10 } else { 4 << 20 };
    let mut json_rows: Vec<String> = Vec::new();

    let mut table = Table::new(&["devices", "write", "write Gbit/s", "read", "read Gbit/s"]);
    for &n in device_counts {
        let t = Topology::star(0xB3C4, n, 1, LinkConfig::dc_100g());
        let mut cl = t.cluster;
        let mut eng: Engine<Cluster> = Engine::new();
        let map = InterleaveMap::paper_default((1..=n as u8).map(DeviceIp::lan).collect());
        let mut ctl = SdnController::new(map, 2 << 30);
        ctl.grant_host(&mut cl, 1, DeviceIp::lan(101));
        let lease = ctl
            .malloc_mapped(&mut cl, 1, bytes as u64, true)
            .expect("pool lease");
        let client =
            MemClient::new(t.hosts[0], DeviceIp::lan(101), 1, ctl.map().clone()).with_window(8);
        let data = vec![0x5Au8; bytes];
        let t0 = eng.now();
        client
            .write(&mut cl, &mut eng, lease.gva, &data)
            .expect("pooled write");
        let t_write = eng.now() - t0;
        let t0 = eng.now();
        let back = client
            .read(&mut cl, &mut eng, lease.gva, bytes)
            .expect("pooled read");
        let t_read = eng.now() - t0;
        assert_eq!(back, data, "round trip through the pool");
        table.row(&[
            n.to_string(),
            fmt_ns(t_write),
            format!("{:.1}", gbps(bytes, t_write)),
            fmt_ns(t_read),
            format!("{:.1}", gbps(bytes, t_read)),
        ]);
        for (mode, ns) in [("write", t_write), ("read", t_read)] {
            json_rows.push(format!(
                "    {{\"grid\": \"bandwidth\", \"mode\": \"{mode}\", \"devices\": {n}, \
                 \"bytes\": {bytes}, \"elapsed_ns\": {ns}, \"gbps\": {:.3}}}",
                gbps(bytes, ns)
            ));
        }
    }
    println!("## {bytes} B scatter-gather vs pool width\n\n{}", table.render());

    // E3: direct single-device incast vs the interleaved pool path.
    let cfg = E3Config {
        bytes_per_sender: if smoke { 256 << 10 } else { 2 << 20 },
        ..Default::default()
    };
    let r = run_e3(&cfg).expect("e3");
    println!(
        "## E3 incast ({} senders x {} B)\n\n{}",
        cfg.senders,
        cfg.bytes_per_sender,
        r.table.render()
    );
    json_rows.push(format!(
        "    {{\"grid\": \"incast\", \"arm\": \"direct\", \"senders\": {}, \"bytes_per_sender\": {}, \
         \"elapsed_ns\": {}, \"drops\": {}, \"retransmits\": {}}}",
        cfg.senders, cfg.bytes_per_sender, r.direct_ns, r.direct_drops, r.direct_retransmits
    ));
    json_rows.push(format!(
        "    {{\"grid\": \"incast\", \"arm\": \"pool\", \"senders\": {}, \"bytes_per_sender\": {}, \
         \"elapsed_ns\": {}, \"drops\": {}, \"retransmits\": {}}}",
        cfg.senders, cfg.bytes_per_sender, r.pool_scatter_ns, r.pool_drops, r.pool_retransmits
    ));

    let json = format!(
        "{{\n  \"bench\": \"mempool\",\n  \"smoke\": {smoke},\n  \"results\": [\n{}\n  ]\n}}\n",
        json_rows.join(",\n")
    );
    std::fs::write("BENCH_mempool.json", &json).expect("write BENCH_mempool.json");
    println!("wrote BENCH_mempool.json ({} rows)", json_rows.len());
    println!("bench wallclock: {:.2?}", wall.elapsed());
}
