//! Bench — the pooled-memory data plane (§2.5/§2.6).
//!
//! Grid 1: scatter-gather read/write bandwidth through `MemClient` as the
//! pool widens (1 → 8 devices; the host link is the roofline, the grid
//! shows how well per-device windows keep the pipe full).
//! Grid 2: the E3 incast contrast — N senders into one device (drops,
//! retransmit storm) vs the same bytes interleaved over the pool and
//! pulled back with paced READs, all through controller-programmed
//! IOMMUs.
//! Grid 3: paced vs unpaced pull-back — the same aggregate read through
//! `MemClient` with the window engine's token bucket at several rates
//! (paced goodput must track the configured rate, unpaced the roofline).
//! Grid 4: pipelined-batch-depth sweep — N fixed-size reads issued
//! through `MemBatch` at varying batch depth (1 = the old one-call-at-a-
//! time API; deeper batches keep every device window full across
//! logical ops).
//!
//! Writes the machine-readable artifact `BENCH_mempool.json`. Set
//! `NETDAM_BENCH_SMOKE=1` for a tiny CI-sized run.

use netdam::coordinator::{run_e3, E3Config};
use netdam::mem::MemClient;
use netdam::metrics::Table;
use netdam::net::{Cluster, LinkConfig, Topology};
use netdam::pool::{InterleaveMap, SdnController};
use netdam::sim::{fmt_ns, Engine};
use netdam::wire::DeviceIp;

fn gbps(bytes: usize, ns: u64) -> f64 {
    bytes as f64 * 8.0 / ns.max(1) as f64
}

fn main() {
    let wall = std::time::Instant::now();
    let smoke = std::env::var("NETDAM_BENCH_SMOKE").is_ok();
    println!("# Pooled-memory grid (controller -> IOMMU -> MemClient)\n");

    let device_counts: &[usize] = if smoke { &[2] } else { &[1, 2, 4, 8] };
    let bytes = if smoke { 256 << 10 } else { 4 << 20 };
    let mut json_rows: Vec<String> = Vec::new();

    let mut table = Table::new(&["devices", "write", "write Gbit/s", "read", "read Gbit/s"]);
    for &n in device_counts {
        let t = Topology::star(0xB3C4, n, 1, LinkConfig::dc_100g());
        let mut cl = t.cluster;
        let mut eng: Engine<Cluster> = Engine::new();
        let map = InterleaveMap::paper_default((1..=n as u8).map(DeviceIp::lan).collect());
        let mut ctl = SdnController::new(map, 2 << 30);
        ctl.grant_host(&mut cl, 1, DeviceIp::lan(101));
        let lease = ctl
            .malloc_mapped(&mut cl, 1, bytes as u64, true)
            .expect("pool lease");
        let client =
            MemClient::new(t.hosts[0], DeviceIp::lan(101), 1, ctl.map().clone()).with_window(8);
        let data = vec![0x5Au8; bytes];
        let t0 = eng.now();
        client
            .write(&mut cl, &mut eng, lease.gva, &data)
            .expect("pooled write");
        let t_write = eng.now() - t0;
        let t0 = eng.now();
        let back = client
            .read(&mut cl, &mut eng, lease.gva, bytes)
            .expect("pooled read");
        let t_read = eng.now() - t0;
        assert_eq!(back, data, "round trip through the pool");
        table.row(&[
            n.to_string(),
            fmt_ns(t_write),
            format!("{:.1}", gbps(bytes, t_write)),
            fmt_ns(t_read),
            format!("{:.1}", gbps(bytes, t_read)),
        ]);
        for (mode, ns) in [("write", t_write), ("read", t_read)] {
            json_rows.push(format!(
                "    {{\"grid\": \"bandwidth\", \"mode\": \"{mode}\", \"devices\": {n}, \
                 \"bytes\": {bytes}, \"elapsed_ns\": {ns}, \"gbps\": {:.3}}}",
                gbps(bytes, ns)
            ));
        }
    }
    println!("## {bytes} B scatter-gather vs pool width\n\n{}", table.render());

    // Grid 3: paced vs unpaced pull-back over a 4-device pool.
    let pull_bytes = if smoke { 256 << 10 } else { 2 << 20 };
    let mut table = Table::new(&["pull mode", "elapsed", "goodput Gbit/s"]);
    for (label, pace_gbps) in [
        ("unpaced", None),
        ("paced 50 Gbit/s", Some(50.0)),
        ("paced 92 Gbit/s", Some(92.0)),
    ] {
        let t = Topology::star(0xACED_0711, 4, 1, LinkConfig::dc_100g());
        let mut cl = t.cluster;
        let mut eng: Engine<Cluster> = Engine::new();
        let map = InterleaveMap::paper_default((1..=4).map(DeviceIp::lan).collect());
        let mut ctl = SdnController::new(map, 2 << 30);
        ctl.grant_host(&mut cl, 1, DeviceIp::lan(101));
        let lease = ctl
            .malloc_mapped(&mut cl, 1, pull_bytes as u64, true)
            .expect("pool lease");
        let writer =
            MemClient::new(t.hosts[0], DeviceIp::lan(101), 1, ctl.map().clone()).with_window(8);
        let data = vec![0x3Cu8; pull_bytes];
        writer
            .write(&mut cl, &mut eng, lease.gva, &data)
            .expect("seed write");
        let mut puller =
            MemClient::new(t.hosts[0], DeviceIp::lan(101), 1, ctl.map().clone()).with_window(8);
        if let Some(g) = pace_gbps {
            puller = puller.with_pace(g, 16 << 10);
        }
        let t0 = eng.now();
        let back = puller
            .read(&mut cl, &mut eng, lease.gva, pull_bytes)
            .expect("pull-back");
        let ns = (eng.now() - t0).max(1);
        assert_eq!(back, data);
        table.row(&[
            label.to_string(),
            fmt_ns(ns),
            format!("{:.1}", gbps(pull_bytes, ns)),
        ]);
        json_rows.push(format!(
            "    {{\"grid\": \"paced_pull\", \"mode\": \"{label}\", \"bytes\": {pull_bytes}, \
             \"elapsed_ns\": {ns}, \"gbps\": {:.3}}}",
            gbps(pull_bytes, ns)
        ));
    }
    println!("## {pull_bytes} B pull-back: paced vs unpaced\n\n{}", table.render());

    // Grid 4: pipelined-batch-depth sweep (N reads via MemBatch).
    let n_reads = if smoke { 8 } else { 32 };
    let chunk = 64 << 10;
    let depths: &[usize] = if smoke { &[1, 4] } else { &[1, 2, 4, 8, 16] };
    {
        let t = Topology::star(0xBA7C4, 4, 1, LinkConfig::dc_100g());
        let mut cl = t.cluster;
        let mut eng: Engine<Cluster> = Engine::new();
        let map = InterleaveMap::paper_default((1..=4).map(DeviceIp::lan).collect());
        let mut ctl = SdnController::new(map, 2 << 30);
        ctl.grant_host(&mut cl, 1, DeviceIp::lan(101));
        let lease = ctl
            .malloc_mapped(&mut cl, 1, (n_reads * chunk) as u64, true)
            .expect("pool lease");
        let client =
            MemClient::new(t.hosts[0], DeviceIp::lan(101), 1, ctl.map().clone()).with_window(8);
        let data: Vec<u8> = (0..n_reads * chunk).map(|i| (i % 253) as u8).collect();
        client
            .write(&mut cl, &mut eng, lease.gva, &data)
            .expect("seed write");
        let mut table = Table::new(&["batch depth", "elapsed", "goodput Gbit/s"]);
        for &depth in depths {
            let t0 = eng.now();
            let mut i = 0usize;
            while i < n_reads {
                let take = depth.min(n_reads - i);
                let mut batch = client.batch();
                let handles: Vec<_> = (0..take)
                    .map(|k| {
                        batch.read(&mut cl, lease.gva + ((i + k) * chunk) as u64, chunk)
                    })
                    .collect();
                let mut res = batch.run(&mut cl, &mut eng).expect("batch run");
                for (k, h) in handles.into_iter().enumerate() {
                    let got = res.take_read(h).expect("read buffer");
                    let off = (i + k) * chunk;
                    assert_eq!(got[..], data[off..off + chunk], "read {}", i + k);
                }
                i += take;
            }
            let ns = (eng.now() - t0).max(1);
            table.row(&[
                depth.to_string(),
                fmt_ns(ns),
                format!("{:.1}", gbps(n_reads * chunk, ns)),
            ]);
            json_rows.push(format!(
                "    {{\"grid\": \"batch_depth\", \"depth\": {depth}, \"reads\": {n_reads}, \
                 \"chunk\": {chunk}, \"elapsed_ns\": {ns}, \"gbps\": {:.3}}}",
                gbps(n_reads * chunk, ns)
            ));
        }
        println!(
            "## {n_reads} x {chunk} B reads vs pipelined batch depth\n\n{}",
            table.render()
        );
    }

    // E3: direct single-device incast vs the interleaved pool path.
    let cfg = E3Config {
        bytes_per_sender: if smoke { 256 << 10 } else { 2 << 20 },
        ..Default::default()
    };
    let r = run_e3(&cfg).expect("e3");
    println!(
        "## E3 incast ({} senders x {} B)\n\n{}",
        cfg.senders,
        cfg.bytes_per_sender,
        r.table.render()
    );
    json_rows.push(format!(
        "    {{\"grid\": \"incast\", \"arm\": \"direct\", \"senders\": {}, \"bytes_per_sender\": {}, \
         \"elapsed_ns\": {}, \"drops\": {}, \"retransmits\": {}}}",
        cfg.senders, cfg.bytes_per_sender, r.direct_ns, r.direct_drops, r.direct_retransmits
    ));
    json_rows.push(format!(
        "    {{\"grid\": \"incast\", \"arm\": \"pool\", \"senders\": {}, \"bytes_per_sender\": {}, \
         \"elapsed_ns\": {}, \"drops\": {}, \"retransmits\": {}}}",
        cfg.senders, cfg.bytes_per_sender, r.pool_scatter_ns, r.pool_drops, r.pool_retransmits
    ));

    let json = format!(
        "{{\n  \"bench\": \"mempool\",\n  \"smoke\": {smoke},\n  \"results\": [\n{}\n  ]\n}}\n",
        json_rows.join(",\n")
    );
    std::fs::write("BENCH_mempool.json", &json).expect("write BENCH_mempool.json");
    println!("wrote BENCH_mempool.json ({} rows)", json_rows.len());
    println!("bench wallclock: {:.2?}", wall.elapsed());
}
