//! Bench `serving` — the multi-tenant KV/embedding serving tier
//! (`netdam::serve`) across the tenant-count x Zipf-skew x cc-mode
//! grid, reporting per-tenant p50/p99/p99.9 latency, goodput, and
//! NAK/CNP counters per cell. Writes the machine-readable artifact
//! `BENCH_serving.json`; the in-bench row-count assertion plus the CI
//! python check make a silently skipped cell a hard failure.
//!
//! Set `NETDAM_BENCH_SMOKE=1` for a small grid (CI smoke). The full
//! grid leases each fleet out of a 2 GiB pooled GVA space (8 devices x
//! 256 MiB) — the devices' HBM backing is page-sparse, so only touched
//! pages cost host memory.

use netdam::metrics::Table;
use netdam::roce::DcqcnConfig;
use netdam::serve::{run, ServeConfig, ServeReport};
use netdam::sim::fmt_ns;
use netdam::transport::CcMode;

fn cc_of(name: &str) -> CcMode {
    match name {
        "dcqcn" => CcMode::Dcqcn(DcqcnConfig::default()),
        _ => CcMode::Static,
    }
}

fn cell_cfg(smoke: bool, tenants: usize, skew: f64, cc: &str) -> ServeConfig {
    let base = if smoke {
        ServeConfig {
            devices: 4,
            keys_per_tenant: 128,
            value_bytes: 256,
            waves: 2,
            ops_per_wave: 8,
            pool_per_device: 4 << 20,
            ..Default::default()
        }
    } else {
        ServeConfig {
            devices: 8,
            keys_per_tenant: 8192,
            value_bytes: 512,
            waves: 6,
            ops_per_wave: 32,
            pool_per_device: 256 << 20, // 8 devices -> a 2 GiB GVA pool
            ..Default::default()
        }
    };
    ServeConfig {
        tenants,
        skew,
        cc: cc_of(cc),
        seed: 0x5E24E,
        shard_threads: 0,
        ..base
    }
}

fn json_u64s(xs: impl Iterator<Item = u64>) -> String {
    let v: Vec<String> = xs.map(|x| x.to_string()).collect();
    format!("[{}]", v.join(", "))
}

fn json_f64s(xs: impl Iterator<Item = f64>) -> String {
    let v: Vec<String> = xs.map(|x| format!("{x:.3}")).collect();
    format!("[{}]", v.join(", "))
}

fn row_json(cfg: &ServeConfig, cc: &str, r: &ServeReport, wall_ms: f64) -> String {
    let requests: usize = r.tenants.iter().map(|t| t.requests).sum();
    let naks: usize = r.tenants.iter().map(|t| t.naks).sum();
    let cancelled: usize = r.tenants.iter().map(|t| t.cancelled).sum();
    let agg_goodput: f64 = r.tenants.iter().map(|t| t.goodput_gbps).sum();
    format!(
        "    {{\"tenants\": {}, \"skew\": {}, \"cc\": \"{cc}\", \"devices\": {}, \
         \"keys_per_tenant\": {}, \"value_bytes\": {}, \"waves\": {}, \"ops_per_wave\": {}, \
         \"requests\": {requests}, \"elapsed_ns\": {}, \"wall_ms\": {wall_ms:.3}, \
         \"p50_ns\": {}, \"p99_ns\": {}, \"p999_ns\": {}, \"goodput_gbps\": {}, \
         \"agg_goodput_gbps\": {agg_goodput:.3}, \"naks\": {naks}, \"cancelled\": {cancelled}, \
         \"retx\": {}, \"cnps\": {}, \"churn_events\": {}}}",
        cfg.tenants,
        cfg.skew,
        cfg.devices,
        cfg.keys_per_tenant,
        cfg.value_bytes,
        cfg.waves,
        cfg.ops_per_wave,
        r.elapsed_ns,
        json_u64s(r.tenants.iter().map(|t| t.tail.p50)),
        json_u64s(r.tenants.iter().map(|t| t.tail.p99)),
        json_u64s(r.tenants.iter().map(|t| t.tail.p999)),
        json_f64s(r.tenants.iter().map(|t| t.goodput_gbps)),
        r.retransmits,
        r.cnps,
        r.churn_events,
    )
}

fn main() {
    let wall_total = std::time::Instant::now();
    let smoke = std::env::var("NETDAM_BENCH_SMOKE").is_ok();
    let (tenant_grid, skew_grid): (&[usize], &[f64]) = if smoke {
        (&[2, 3], &[0.0, 0.99])
    } else {
        (&[4, 8, 16], &[0.0, 0.9, 1.2])
    };
    let cc_grid = ["static", "dcqcn"];
    let expected_rows = tenant_grid.len() * skew_grid.len() * cc_grid.len();
    println!(
        "# serving — multi-tenant KV/embedding tier: {} tenant-counts x {} skews x \
         {} cc-modes ({expected_rows} cells)\n",
        tenant_grid.len(),
        skew_grid.len(),
        cc_grid.len()
    );

    let mut table = Table::new(&[
        "tenants", "skew", "cc", "worst p99", "worst p99.9", "fleet goodput", "cnps", "wall",
    ]);
    let mut json_rows: Vec<String> = Vec::new();
    for &tenants in tenant_grid {
        for &skew in skew_grid {
            for cc in cc_grid {
                let cfg = cell_cfg(smoke, tenants, skew, cc);
                let wall = std::time::Instant::now();
                let r = run(&cfg).expect("serving cell");
                let wall_ms = wall.elapsed().as_secs_f64() * 1e3;
                // Every cell must complete its whole schedule NAK-free —
                // a stranded or NAK'd fleet is a bench failure, not a
                // quieter report.
                for t in &r.tenants {
                    assert_eq!(t.done, t.ops, "cell {tenants}/{skew}/{cc}: stranded ops");
                    assert_eq!(t.naks, 0, "cell {tenants}/{skew}/{cc}: unexpected NAK");
                    assert!(t.tail.count > 0, "cell {tenants}/{skew}/{cc}: no latencies");
                }
                let worst_p999 = r.tenants.iter().map(|t| t.tail.p999).max().unwrap_or(0);
                table.row(&[
                    tenants.to_string(),
                    format!("{skew}"),
                    cc.to_string(),
                    fmt_ns(r.worst_p99()),
                    fmt_ns(worst_p999),
                    format!(
                        "{:.2} Gbps",
                        r.tenants.iter().map(|t| t.goodput_gbps).sum::<f64>()
                    ),
                    r.cnps.to_string(),
                    format!("{wall_ms:.0} ms"),
                ]);
                json_rows.push(row_json(&cfg, cc, &r, wall_ms));
            }
        }
    }
    println!("{}", table.render());

    assert_eq!(
        json_rows.len(),
        expected_rows,
        "a grid cell was silently skipped: {}/{expected_rows} rows",
        json_rows.len()
    );

    let json = format!(
        "{{\n  \"bench\": \"serving\",\n  \"smoke\": {smoke},\n  \"meta\": {{\"expected_rows\": \
         {expected_rows}, \"tenant_grid\": {:?}, \"skew_grid\": {:?}, \"cc_grid\": \
         [\"static\", \"dcqcn\"], \"total_wall_ms\": {:.3}}},\n  \"results\": [\n{}\n  ]\n}}\n",
        tenant_grid,
        skew_grid,
        wall_total.elapsed().as_secs_f64() * 1e3,
        json_rows.join(",\n")
    );
    std::fs::write("BENCH_serving.json", &json).expect("write BENCH_serving.json");
    println!("wrote BENCH_serving.json ({} rows)", json_rows.len());
    println!("bench wallclock: {:.2?}", wall_total.elapsed());
}
