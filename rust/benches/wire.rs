//! Bench E7 — the wire codec (paper §2.2, Figure 3): encode/decode
//! throughput of the full IPv4/UDP/NetDAM byte format, and DES event
//! throughput (the § Perf L3 headline number).

use netdam::isa::{Instruction, SimdOp};
use netdam::metrics::Table;
use netdam::net::{Cluster, LinkConfig, Topology};
use netdam::sim::Engine;
use netdam::wire::{DeviceIp, Packet, Payload, SrouHeader};

fn main() {
    let wall = std::time::Instant::now();
    println!("# E7 — wire format + DES throughput\n");

    // --- codec ----------------------------------------------------------
    let mk = |payload: usize| {
        Packet::new(
            DeviceIp::lan(1),
            77,
            SrouHeader::direct(DeviceIp::lan(2)),
            Instruction::Simd {
                op: SimdOp::Add,
                addr: 0x8000,
            },
        )
        .with_payload(Payload::from_bytes(vec![0xA5; payload]))
    };
    let mut t = Table::new(&["payload B", "encode Mpkt/s", "decode Mpkt/s", "GB/s decoded"]);
    for payload in [0usize, 128, 2048, 8192] {
        let pkt = mk(payload);
        let n = 200_000;
        let t0 = std::time::Instant::now();
        let mut bytes = Vec::new();
        for _ in 0..n {
            bytes = pkt.encode().unwrap();
            std::hint::black_box(&bytes);
        }
        let enc = t0.elapsed();
        let t1 = std::time::Instant::now();
        for _ in 0..n {
            let p = Packet::decode(&bytes).unwrap();
            std::hint::black_box(&p);
        }
        let dec = t1.elapsed();
        t.row(&[
            payload.to_string(),
            format!("{:.2}", n as f64 / enc.as_micros() as f64),
            format!("{:.2}", n as f64 / dec.as_micros() as f64),
            format!("{:.2}", (n * bytes.len()) as f64 / dec.as_nanos() as f64),
        ]);
    }
    println!("## codec round trip\n\n{}", t.render());

    // --- DES event throughput -------------------------------------------
    // A read-request storm across the testbed: measures events/second,
    // the number that bounds paper-scale runs (§ Perf).
    let t0 = std::time::Instant::now();
    let topo = Topology::star(1, 4, 1, LinkConfig::dc_100g());
    let mut cl = topo.cluster;
    let host = topo.hosts[0];
    let mut eng: Engine<Cluster> = Engine::new();
    let n_req = 50_000usize;
    for i in 0..n_req {
        let dst = DeviceIp::lan(1 + (i % 4) as u8);
        let seq = cl.alloc_seq(host);
        let pkt = Packet::new(
            DeviceIp::lan(101),
            seq,
            SrouHeader::direct(dst),
            Instruction::Read { addr: 0, len: 128 },
        );
        let at = (i as u64) * 200; // 5 Mpps offered
        eng.schedule_at(at, move |cl: &mut Cluster, eng| {
            cl.send_from(eng, host, pkt);
        });
    }
    eng.run(&mut cl);
    let dt = t0.elapsed();
    let events = eng.events_processed();
    println!("## DES throughput\n");
    println!(
        "{n_req} READ round-trips -> {events} events in {:.2?} = {:.2} M events/s",
        dt,
        events as f64 / dt.as_micros() as f64
    );
    println!("\nbench wallclock: {:.2?}", wall.elapsed());
}
