//! Bench E1 — regenerates the §2.3 latency numbers (avg 618 / jitter 39 /
//! max 920 ns wire-to-wire, "much faster than RoCE").
//!
//! `cargo bench --bench latency`

use netdam::coordinator::{run_e1, E1Config};

fn main() {
    println!("# E1 — wire-to-wire SIMD READ latency (paper §2.3)\n");
    let wall = std::time::Instant::now();
    let cfg = E1Config {
        read_len: 128,
        samples: 50_000,
        seed: 0xE1,
    };
    let r = run_e1(&cfg);
    println!("{}", r.table.render());
    println!(
        "paper: NetDAM avg 618 ns, jitter 39 ns, max 920 ns | measured: {:.0}/{:.0}/{}",
        r.device.mean, r.device.jitter, r.device.max
    );
    println!(
        "RoCE/NetDAM RTT ratio: {:.2}x mean, {:.2}x p99",
        r.roce_rtt.mean / r.netdam_rtt.mean,
        r.roce_rtt.p99 as f64 / r.netdam_rtt.p99 as f64,
    );

    // Sweep the READ size to show the fixed-pipeline scaling.
    println!("\n## READ size sweep (device wire-to-wire)\n");
    let mut t = netdam::metrics::Table::new(&["read bytes", "avg ns", "jitter ns", "max ns"]);
    for len in [64u32, 128, 512, 2048, 8192] {
        let r = run_e1(&E1Config {
            read_len: len,
            samples: 10_000,
            seed: 0xE1,
        });
        t.row(&[
            len.to_string(),
            format!("{:.0}", r.device.mean),
            format!("{:.0}", r.device.jitter),
            r.device.max.to_string(),
        ]);
    }
    println!("{}", t.render());
    println!("bench wallclock: {:.2?}", wall.elapsed());
}
