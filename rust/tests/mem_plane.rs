//! End-to-end memory-plane integration (§2.5/§2.6, public API): the SDN
//! controller programs device IOMMUs from `malloc_mapped`, `MemClient`
//! drives GVA scatter-gather plans over the live fabric, and access
//! control is enforced *by the devices* — denials arrive as wire-level
//! NAKs (typed `MemError::Nak`), observable as `iommu_naks` on the
//! device counters, never as host-side `AllocError`s.

use netdam::iommu::NakReason;
use netdam::mem::{MemClient, MemError};
use netdam::net::{Cluster, LinkConfig, NodeId, Topology};
use netdam::pool::{InterleaveMap, SdnController};
use netdam::sim::Engine;
use netdam::wire::DeviceIp;

struct World {
    cl: Cluster,
    eng: Engine<Cluster>,
    ctl: SdnController,
    devices: Vec<NodeId>,
    hosts: Vec<NodeId>,
}

/// 4 pool devices + 2 hosts; host 0 is tenant 1, host 1 is tenant 2.
fn world() -> World {
    let t = Topology::star(0xE2E, 4, 2, LinkConfig::dc_100g());
    let mut cl = t.cluster;
    let map = InterleaveMap::paper_default((1..=4).map(DeviceIp::lan).collect());
    let ctl = SdnController::new(map, 1 << 20);
    ctl.grant_host(&mut cl, 1, DeviceIp::lan(101));
    ctl.grant_host(&mut cl, 2, DeviceIp::lan(102));
    World {
        cl,
        eng: Engine::new(),
        ctl,
        devices: t.devices,
        hosts: t.hosts,
    }
}

fn client(w: &World, host: usize, tenant: u32) -> MemClient {
    MemClient::new(
        w.hosts[host],
        DeviceIp::lan(101 + host as u8),
        tenant,
        w.ctl.map().clone(),
    )
}

fn total_naks(w: &World) -> u64 {
    w.devices.iter().map(|&d| w.cl.device(d).iommu_naks).sum()
}

#[test]
fn gva_round_trip_spans_the_whole_pool() {
    let mut w = world();
    let a = w.ctl.malloc_mapped(&mut w.cl, 1, 128 << 10, true).unwrap();
    let c = client(&w, 0, 1);
    let data: Vec<u8> = (0..128 << 10).map(|i| (i * 7 % 255) as u8).collect();
    c.write(&mut w.cl, &mut w.eng, a.gva, &data).unwrap();
    let back = c.read(&mut w.cl, &mut w.eng, a.gva, data.len()).unwrap();
    assert_eq!(back, data);
    // All four devices carried pool traffic through programmed IOMMUs.
    for &d in &w.devices {
        let dev = w.cl.device(d);
        assert!(dev.pkts_in > 0, "device {d} untouched");
        assert!(!dev.iommu_ref().is_identity(), "IOMMU not programmed");
    }
    assert_eq!(total_naks(&w), 0);
}

#[test]
fn cross_tenant_isolation_is_device_enforced() {
    let mut w = world();
    let a = w.ctl.malloc_mapped(&mut w.cl, 1, 32 << 10, true).unwrap();
    let owner = client(&w, 0, 1);
    let other = client(&w, 1, 2);
    owner
        .write(&mut w.cl, &mut w.eng, a.gva, &[0xAB; 4096])
        .unwrap();
    // Tenant 2 (a *valid* tenant, just not the lessee) reads tenant 1's
    // lease: the device IOMMU fences it with a ForeignLease NAK.
    let err = other.read(&mut w.cl, &mut w.eng, a.gva, 4096).unwrap_err();
    match err {
        MemError::Nak { reason, gva, .. } => {
            assert_eq!(reason, NakReason::ForeignLease);
            assert_eq!(gva, a.gva);
        }
        other => panic!("expected a NAK, got {other:?}"),
    }
    assert!(total_naks(&w) >= 1, "the denial happened on a device");
    // The owner is unaffected.
    let back = owner.read(&mut w.cl, &mut w.eng, a.gva, 4096).unwrap();
    assert_eq!(back, vec![0xAB; 4096]);
}

#[test]
fn readonly_violation_naks_with_write_denied() {
    let mut w = world();
    let ro = w.ctl.malloc_mapped(&mut w.cl, 2, 8192, false).unwrap();
    let c = client(&w, 1, 2);
    let err = c
        .write(&mut w.cl, &mut w.eng, ro.gva, &[1u8; 256])
        .unwrap_err();
    assert!(
        matches!(
            err,
            MemError::Nak {
                reason: NakReason::WriteDenied,
                ..
            }
        ),
        "{err:?}"
    );
    assert!(total_naks(&w) >= 1);
    // And the lease still reads clean.
    assert_eq!(
        c.read(&mut w.cl, &mut w.eng, ro.gva, 256).unwrap(),
        vec![0u8; 256]
    );
}

#[test]
fn pooled_cas_lock_word_semantics() {
    let mut w = world();
    let a1 = w.ctl.malloc_mapped(&mut w.cl, 1, 8192, true).unwrap();
    let c1 = client(&w, 0, 1);
    assert_eq!(c1.cas(&mut w.cl, &mut w.eng, a1.gva, 0, 7).unwrap(), (0, true));
    assert_eq!(
        c1.cas(&mut w.cl, &mut w.eng, a1.gva, 0, 9).unwrap(),
        (7, false),
        "lock already held"
    );
    assert_eq!(c1.cas(&mut w.cl, &mut w.eng, a1.gva, 7, 0).unwrap(), (7, true));
}

#[test]
fn gather_program_translates_through_leases() {
    let mut w = world();
    // 16 rows x 1 KiB = 2 blocks across two devices, result on a third.
    let rows = w.ctl.malloc_mapped(&mut w.cl, 1, 16 * 1024, true).unwrap();
    let dst = w.ctl.malloc_mapped(&mut w.cl, 1, 1024, true).unwrap();
    let c = client(&w, 0, 1);
    let mut bytes = Vec::new();
    for r in 0..16u32 {
        bytes.extend(std::iter::repeat((r as f32).to_le_bytes()).take(256).flatten());
    }
    c.write(&mut w.cl, &mut w.eng, rows.gva, &bytes).unwrap();
    let picks: Vec<u64> = vec![rows.gva, rows.gva + 9 * 1024, rows.gva + 15 * 1024];
    c.gather_sum(&mut w.cl, &mut w.eng, &picks, 1024, dst.gva)
        .unwrap();
    let got = c.read(&mut w.cl, &mut w.eng, dst.gva, 1024).unwrap();
    let lane = f32::from_le_bytes(got[..4].try_into().unwrap());
    assert_eq!(lane, 24.0, "0 + 9 + 15 reduced near memory");
    assert_eq!(total_naks(&w), 0);
    // A gather touching rows outside the lease NAKs like everything else.
    let err = c
        .gather_sum(&mut w.cl, &mut w.eng, &[1 << 19], 1024, dst.gva)
        .unwrap_err();
    assert!(matches!(err, MemError::Nak { .. }), "{err:?}");
}

/// A NAK mid-plan cancels the remaining window cleanly: queued ops are
/// dropped (not hammered into more NAKs), in-flight ops drain, no
/// reliability timers dangle, no completion hook leaks — and the client
/// is immediately usable again on the same fabric.
#[test]
fn nak_mid_plan_cancels_and_drains_cleanly() {
    let mut w = world();
    // 16 KiB lease but a 64 KiB read: the tail pieces fall outside the
    // lease and fault Unmapped on their devices. window=1 keeps most of
    // the plan queued when the first NAK lands, exercising cancellation.
    let a = w.ctl.malloc_mapped(&mut w.cl, 1, 16 << 10, true).unwrap();
    let c = client(&w, 0, 1).with_window(1);
    let err = c.read(&mut w.cl, &mut w.eng, a.gva, 64 << 10).unwrap_err();
    assert!(
        matches!(err, MemError::Nak { reason: NakReason::Unmapped, .. }),
        "{err:?}"
    );
    // Clean teardown: the engine removed its hook and every injected
    // reliable op was completed (acked or NAK'd) — nothing still pending.
    assert!(w.cl.on_completion.is_none(), "completion hook leaked");
    assert_eq!(
        w.cl.xport.outstanding(),
        0,
        "dangling reliability timers after NAK cancellation"
    );
    // The same client works right away: the cancelled plan left no state.
    c.write(&mut w.cl, &mut w.eng, a.gva, &[7u8; 4096]).unwrap();
    assert_eq!(
        c.read(&mut w.cl, &mut w.eng, a.gva, 4096).unwrap(),
        vec![7u8; 4096]
    );
    // And the host mailbox holds no orphaned responses from the
    // cancelled plan (they were drained with it).
    let mailbox_len = {
        let h = w.cl.host_mut(w.hosts[0]);
        h.mailbox.len()
    };
    assert_eq!(mailbox_len, 0, "orphaned responses left in the mailbox");
}
