//! Property-style integration tests over the whole fabric
//! (`util::prop` is the offline stand-in for proptest — see DESIGN.md).

use netdam::collectives::{oracle_sum, read_vector, run_ring_allreduce, seed_gradients, RingSpec};
use netdam::device::DeviceConfig;
use netdam::isa::registry::MemAccess;
use netdam::isa::{Flags, Instruction};
use netdam::net::{Cluster, EcmpMode, LinkConfig, Topology};
use netdam::pool::InterleaveMap;
use netdam::sim::Engine;
use netdam::util::bytes::f32s_to_bytes;
use netdam::util::{prop, Xoshiro256};
use netdam::wire::{DeviceIp, Packet, Payload, SrouHeader};

/// Random remote writes through the fabric land byte-exactly, regardless
/// of size, alignment and interleaving of requests.
#[test]
fn random_remote_writes_read_back_exactly() {
    prop::check_with(prop::Config { seed: 0xFAB, cases: 24 }, |rng, case| {
        let t = Topology::star(case as u64, 2, 1, LinkConfig::dc_100g());
        let mut cl = t.cluster;
        let host = t.hosts[0];
        let host_ip = DeviceIp::lan(101);
        let mut eng: Engine<Cluster> = Engine::new();
        // Up to 8 writes at random addresses/lengths (no overlap: spaced).
        let n_writes = 1 + rng.next_below(8) as usize;
        let mut blobs = Vec::new();
        for w in 0..n_writes {
            let len = 4 * (1 + prop::log_size(rng, 2048));
            let addr = (w as u64) * 65536 + rng.next_below(1024) * 4;
            let data = rng.f32_vec(len / 4, -1e3, 1e3);
            let seq = cl.alloc_seq(host);
            let pkt = Packet::new(
                host_ip,
                seq,
                SrouHeader::direct(DeviceIp::lan(1)),
                Instruction::Write { addr },
            )
            .with_flags(Flags(Flags::RELIABLE))
            .with_payload(Payload::from_f32s(&data));
            cl.inject(&mut eng, host, pkt);
            blobs.push((addr, data));
        }
        eng.run(&mut cl);
        let d1 = cl.node_by_ip(DeviceIp::lan(1)).unwrap();
        for (addr, data) in blobs {
            let got = cl
                .device_mut(d1)
                .mem()
                .read(addr, data.len() * 4)
                .unwrap();
            assert_eq!(got, f32s_to_bytes(&data));
        }
    });
}

/// Allreduce is exact for arbitrary rank counts (2..=8), element counts
/// and windows, on both star and fat-tree fabrics.
#[test]
fn allreduce_exact_over_random_configs() {
    prop::check_with(prop::Config { seed: 0xA11, cases: 10 }, |rng, case| {
        let ranks = 2 + rng.next_below(7) as usize; // 2..=8
        let blocks_per_chunk = 1 + rng.next_below(3) as usize;
        let lanes = 2048usize;
        let elements = ranks * blocks_per_chunk * lanes;
        let window = 1 + rng.next_below(8) as usize;
        let fat_tree = rng.chance(0.5);
        let (mut cl, devices) = if fat_tree {
            let pods = 2;
            let t = Topology::fat_tree(
                case as u64,
                pods,
                ranks.div_ceil(2),
                2,
                LinkConfig::dc_100g(),
                EcmpMode::FlowHash,
            );
            (t.cluster, t.devices[..ranks].to_vec())
        } else {
            let t = Topology::star(case as u64, ranks, 0, LinkConfig::dc_100g());
            (t.cluster, t.devices)
        };
        let grads = seed_gradients(&mut cl, &devices, elements, 0, case as u64);
        let mut eng: Engine<Cluster> = Engine::new();
        let out = run_ring_allreduce(
            &mut cl,
            &mut eng,
            &devices,
            &RingSpec {
                elements,
                window,
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(out.blocks_done, out.blocks);
        let oracle = oracle_sum(&grads);
        for &d in &devices {
            assert_eq!(
                read_vector(&mut cl, d, 0, elements).unwrap(),
                oracle,
                "ranks={ranks} window={window} fat_tree={fat_tree}"
            );
        }
    });
}

/// The interleave map scatter, executed as real packets through the
/// fabric, reassembles to the original buffer on pull-back.
#[test]
fn pool_scatter_gather_round_trips() {
    let t = Topology::star(3, 4, 1, LinkConfig::dc_100g());
    let mut cl = t.cluster;
    let host = t.hosts[0];
    let host_ip = DeviceIp::lan(101);
    let map = InterleaveMap::paper_default((1..=4).map(DeviceIp::lan).collect());
    let mut rng = Xoshiro256::seed_from(88);
    let data = rng.f32_vec(24 * 1024, -1.0, 1.0); // 96 KiB
    let bytes = f32s_to_bytes(&data);
    let mut eng: Engine<Cluster> = Engine::new();
    for e in map.scatter(0, bytes.len() as u64) {
        let seq = cl.alloc_seq(host);
        let chunk = &bytes[e.range_off as usize..(e.range_off + e.len) as usize];
        let pkt = Packet::new(
            host_ip,
            seq,
            SrouHeader::direct(e.device),
            Instruction::Write { addr: e.local_addr },
        )
        .with_flags(Flags(Flags::RELIABLE))
        .with_payload(Payload::from_bytes(chunk.to_vec()));
        cl.inject(&mut eng, host, pkt);
    }
    eng.run(&mut cl);
    // Reassemble by reading each device's memory directly (memif view).
    let mut back = vec![0u8; bytes.len()];
    for e in map.scatter(0, bytes.len() as u64) {
        let node = cl.node_by_ip(e.device).unwrap();
        let got = cl
            .device_mut(node)
            .mem()
            .read(e.local_addr, e.len as usize)
            .unwrap();
        back[e.range_off as usize..(e.range_off + e.len) as usize].copy_from_slice(&got);
    }
    assert_eq!(back, bytes);
}

/// Ordered flows deliver in sequence order even with duplication faults.
#[test]
fn ordered_flag_restores_sequence_under_duplication() {
    let mut cl = Cluster::new(4);
    let sw = cl.add_switch(netdam::net::Switch::tor(None));
    let h = cl.add_host(DeviceIp::lan(101), None);
    let d = cl.add_device(DeviceConfig::paper_default(DeviceIp::lan(1)));
    cl.connect(sw, h, LinkConfig::dc_100g());
    cl.connect(sw, d, LinkConfig::dc_100g());
    cl.compute_routes();
    cl.fault.dup_p = 0.2;
    let mut eng: Engine<Cluster> = Engine::new();
    // Writes 1..=20 all target the same address; ordered delivery means
    // the final value is from seq 20.
    for i in 1..=20u64 {
        let pkt = Packet::new(
            DeviceIp::lan(101),
            i,
            SrouHeader::direct(DeviceIp::lan(1)),
            Instruction::Write { addr: 0 },
        )
        .with_flags(Flags(Flags::ORDERED))
        .with_payload(Payload::from_f32s(&[i as f32]));
        cl.inject(&mut eng, h, pkt);
    }
    eng.run(&mut cl);
    let node = cl.node_by_ip(DeviceIp::lan(1)).unwrap();
    let got = cl.device_mut(node).mem().read(0, 4).unwrap();
    assert_eq!(got, 20.0f32.to_le_bytes());
}
