//! PR 10 acceptance contract: the serving tier's isolation verdict.
//!
//! One seeded multi-tenant fleet runs twice per shard count — quiet,
//! then with the aggressor (NAK storm from a revoked lease + incast
//! burst) — on a shared DCQCN fabric. Every well-behaved tenant must
//! finish its full schedule NAK-free with its p99 within 2x of the
//! aggressor-free baseline, and both reports must be bit-identical
//! across DES shard counts {1, 2, 4}.

use netdam::roce::DcqcnConfig;
use netdam::serve::{isolation_check, ServeConfig};
use netdam::transport::CcMode;

fn fleet(shards: usize) -> ServeConfig {
    ServeConfig {
        tenants: 4,
        devices: 4,
        keys_per_tenant: 128,
        value_bytes: 512,
        waves: 4,
        ops_per_wave: 24,
        burst_bytes: 64 << 10,
        cc: CcMode::Dcqcn(DcqcnConfig::default()),
        seed: 0x150_1A7E,
        shards,
        shard_threads: 1,
        ..Default::default()
    }
}

#[test]
fn aggressor_cannot_move_a_neighbors_tail_and_shards_agree() {
    let mut prints = Vec::new();
    for shards in [1usize, 2, 4] {
        let v = isolation_check(&fleet(shards), 2_000).expect("isolation A/B");

        // The verdict itself: every neighbor's p99 within 2x of quiet.
        assert!(
            v.ok,
            "shards={shards}: isolation violated (worst inflation {} milli > {} milli)",
            v.worst_ratio_milli, v.bound_milli
        );

        // The aggressor genuinely misbehaved — one NAK'd (and partly
        // cancelled) storm plan per wave — and only in the contended run.
        assert!(v.baseline.aggressor.is_none());
        let agg = v.contended.aggressor.as_ref().unwrap();
        assert!(agg.naks > 0, "shards={shards}: storm never NAK'd");
        assert!(agg.cancelled > 0, "shards={shards}: no storm tail cancelled");

        // Blast radius: the aggressor's failures stay its own. Every
        // well-behaved tenant completes its whole schedule NAK-free in
        // BOTH runs.
        for (which, rep) in [("baseline", &v.baseline), ("contended", &v.contended)] {
            for t in &rep.tenants {
                assert_eq!(t.naks, 0, "shards={shards}/{which}: neighbor NAK'd");
                assert_eq!(t.cancelled, 0, "shards={shards}/{which}: neighbor cancelled");
                assert_eq!(t.done, t.ops, "shards={shards}/{which}: stranded ops");
                assert!(t.tail.p99 > 0, "shards={shards}/{which}: empty tail");
            }
        }

        prints.push((shards, v.baseline.fingerprint(), v.contended.fingerprint()));
    }

    // Cross-shard determinism: the whole A/B — per-tenant counters,
    // byte totals, integer latency tails, fabric clock, retransmit and
    // CNP counts — is bit-identical at every shard count.
    let (_, b1, c1) = &prints[0];
    for (shards, b, c) in &prints[1..] {
        assert_eq!(b, b1, "baseline fingerprint diverges at shards={shards}");
        assert_eq!(c, c1, "contended fingerprint diverges at shards={shards}");
    }
}
