//! Route-computation coverage for the multi-switch topologies
//! (`fat_tree`, `dual_spine`) — previously only the star was exercised.

use netdam::isa::Instruction;
use netdam::net::{Cluster, EcmpMode, LinkConfig, Topology};
use netdam::sim::Engine;
use netdam::wire::{DeviceIp, Packet, SrouHeader};

#[test]
fn fat_tree_fibs_cover_every_pair() {
    let pods = 3;
    let per_leaf = 2;
    let spines = 2;
    let t = Topology::fat_tree(
        1,
        pods,
        per_leaf,
        spines,
        LinkConfig::dc_100g(),
        EcmpMode::FlowHash,
    );
    let n = pods * per_leaf;
    assert_eq!(t.devices.len(), n);
    for i in 0..n {
        for j in 0..n {
            if i == j {
                continue;
            }
            let cands = &t.cluster.fib_of(t.devices[i])[&t.device_ip(j)];
            assert_eq!(cands.len(), 1, "a device has exactly its uplink");
        }
    }
    // Leaf switches: cross-pod destinations fan out over every spine,
    // same-pod destinations use the single downlink.
    for (p, leaf) in t.switches[spines..].iter().enumerate() {
        for j in 0..n {
            let cands = &t.cluster.fib_of(*leaf)[&t.device_ip(j)];
            if j / per_leaf == p {
                assert_eq!(cands.len(), 1, "local device: one downlink");
            } else {
                assert_eq!(cands.len(), spines, "remote device: ECMP over spines");
            }
        }
    }
    // Spine switches reach every device through its leaf (one path).
    for s in &t.switches[..spines] {
        for j in 0..n {
            assert_eq!(t.cluster.fib_of(*s)[&t.device_ip(j)].len(), 1);
        }
    }
}

#[test]
fn fat_tree_groups_follow_leaves() {
    let t = Topology::fat_tree(2, 4, 3, 2, LinkConfig::dc_100g(), EcmpMode::FlowHash);
    assert_eq!(t.leaf_groups.len(), 4);
    for (p, group) in t.leaf_groups.iter().enumerate() {
        assert_eq!(group, &vec![p * 3, p * 3 + 1, p * 3 + 2]);
    }
}

#[test]
fn dual_spine_fibs_are_equal_cost_pairs() {
    let t = Topology::dual_spine(1, 2, LinkConfig::dc_100g(), EcmpMode::FlowHash);
    assert_eq!(t.devices.len(), 4);
    assert_eq!(t.leaf_groups, vec![vec![0, 1], vec![2, 3]]);
    let (leaf1, leaf2) = (t.switches[0], t.switches[1]);
    for leaf in [leaf1, leaf2] {
        for j in 0..4 {
            let cands = &t.cluster.fib_of(leaf)[&t.device_ip(j)];
            let local = (leaf == leaf1) == (j < 2);
            if local {
                assert_eq!(cands.len(), 1, "own device: direct downlink");
            } else {
                assert_eq!(cands.len(), 2, "cross-leaf: both spines equal-cost");
            }
        }
    }
    // Spines themselves are addressable waypoints with routes to them.
    let d0 = t.devices[0];
    assert!(t.cluster.fib_of(d0).contains_key(&DeviceIp::lan(201)));
    assert!(t.cluster.fib_of(d0).contains_key(&DeviceIp::lan(202)));
}

#[test]
fn dual_spine_cross_leaf_read_round_trips() {
    let t = Topology::dual_spine(9, 1, LinkConfig::dc_100g(), EcmpMode::FlowHash);
    let mut cl = t.cluster;
    let from = t.devices[0];
    let target = t.device_ip(1); // other leaf, two spine hops away
    let mut eng: Engine<Cluster> = Engine::new();
    let seq = cl.alloc_seq(from);
    let pkt = Packet::new(
        t.device_ip(0),
        seq,
        SrouHeader::direct(target),
        Instruction::Read { addr: 0, len: 64 },
    );
    cl.inject(&mut eng, from, pkt);
    eng.run(&mut cl);
    let comps = cl.device_mut(from).drain_completions();
    assert_eq!(comps.len(), 1, "read response crossed the spine layer");
    assert_eq!(cl.total_drops(), 0);
}

#[test]
fn collective_on_fat_tree_exercises_cross_pod_routes() {
    // An allreduce whose ring spans pods forces every chain through the
    // spine layer; zero drops proves the FIBs are complete.
    use netdam::collectives::{run_ring_allreduce, RingSpec};
    let t = Topology::fat_tree(6, 2, 2, 2, LinkConfig::dc_100g(), EcmpMode::FlowHash);
    let mut cl = t.cluster;
    let devices = t.devices;
    let elements = 4 * 2048;
    netdam::collectives::seed_gradients(&mut cl, &devices, elements, 0, 4);
    let mut eng: Engine<Cluster> = Engine::new();
    let out = run_ring_allreduce(
        &mut cl,
        &mut eng,
        &devices,
        &RingSpec {
            elements,
            window: 4,
            ..Default::default()
        },
    )
    .unwrap();
    assert_eq!(out.blocks_done, out.blocks);
    assert_eq!(cl.total_drops(), 0);
}
