//! Property tests for the wire layer: `Packet` / `Instruction` /
//! `Program` encode↔decode round-trips under random generation, and
//! truncated-buffer rejection — driven by the in-tree `util::prop`
//! harness (offline stand-in for proptest).

use netdam::isa::{Flags, Instruction, ProgramBuilder, SimdOp, VerifyEnv};
use netdam::util::bytes::{Reader, Writer};
use netdam::util::prop;
use netdam::util::rng::Xoshiro256;
use netdam::wire::{DeviceIp, Packet, Payload, Segment, SrouHeader};

fn rand_op(rng: &mut Xoshiro256) -> SimdOp {
    SimdOp::ALL[rng.next_below(SimdOp::ALL.len() as u64) as usize]
}

fn rand_flags(rng: &mut Xoshiro256) -> Flags {
    Flags((rng.next_u64() & 0x1F) as u16)
}

fn rand_simple_instr(rng: &mut Xoshiro256) -> Instruction {
    use Instruction as I;
    match rng.next_below(18) {
        0 => I::Nop,
        1 => I::Read {
            addr: rng.next_u64(),
            len: rng.next_u32(),
        },
        2 => I::ReadResp { addr: rng.next_u64() },
        3 => I::Write { addr: rng.next_u64() },
        4 => I::WriteAck { addr: rng.next_u64() },
        5 => I::Cas {
            addr: rng.next_u64(),
            expected: rng.next_u64(),
            new: rng.next_u64(),
        },
        6 => I::CasResp {
            addr: rng.next_u64(),
            old: rng.next_u64(),
            swapped: rng.chance(0.5),
        },
        7 => I::Memcopy {
            src: rng.next_u64(),
            dst: rng.next_u64(),
            len: rng.next_u32(),
        },
        8 => I::Ack { acked: rng.next_u64() },
        9 => I::Nack {
            acked: rng.next_u64(),
            reason: (rng.next_u64() & 0xFF) as u8,
        },
        10 => I::Simd {
            op: rand_op(rng),
            addr: rng.next_u64(),
        },
        11 => I::SimdResp { addr: rng.next_u64() },
        12 => I::BlockHash {
            addr: rng.next_u64(),
            len: rng.next_u32(),
        },
        13 => I::BlockHashResp { hash: rng.next_u64() },
        14 => I::WriteIfHash {
            addr: rng.next_u64(),
            expect_hash: rng.next_u64(),
        },
        15 => I::CollectiveDone {
            block: rng.next_u32(),
        },
        16 => I::User {
            opcode: 0x8000 | (rng.next_u32() as u16 & 0x7FFF),
            a: rng.next_u64(),
            b: rng.next_u64(),
            c: rng.next_u64(),
        },
        _ => I::Malloc {
            bytes: rng.next_u64(),
            tag: rng.next_u32(),
        },
    }
}

/// A random (not necessarily verifiable) program through the builder.
fn rand_program(rng: &mut Xoshiro256) -> Instruction {
    let mut b = ProgramBuilder::new().reduce(
        rand_op(rng),
        rng.next_u64(),
        (rng.next_below(4) + 1) as u8,
    );
    if rng.chance(0.7) {
        b = b.guarded_write(rng.next_u64(), rng.next_u64());
    }
    if rng.chance(0.7) {
        b = b.store(rng.next_u64(), (rng.next_below(4) + 1) as u8);
    }
    if rng.chance(0.3) {
        b = b.then(rand_simple_instr_steppable(rng));
    }
    if rng.chance(0.5) {
        b = b.on_retire(rng.next_u32());
    }
    let mut p = b.build_unchecked();
    // Mid-flight cursor values must survive the codec too.
    p.pc = rng.next_below(p.steps.len() as u64 + 1) as u8;
    if (p.pc as usize) < p.steps.len() {
        p.reps_done = rng.next_below(p.steps[p.pc as usize].repeat as u64) as u8;
    }
    Instruction::Program(std::sync::Arc::new(p))
}

/// Step-legal instruction kinds for random fused tails.
fn rand_simple_instr_steppable(rng: &mut Xoshiro256) -> Instruction {
    use Instruction as I;
    match rng.next_below(4) {
        0 => I::Write { addr: rng.next_u64() },
        1 => I::Read {
            addr: rng.next_u64(),
            len: rng.next_u32(),
        },
        2 => I::BlockHash {
            addr: rng.next_u64(),
            len: rng.next_u32(),
        },
        _ => I::User {
            opcode: 0x8000 | (rng.next_u32() as u16 & 0x7FFF),
            a: rng.next_u64(),
            b: rng.next_u64(),
            c: rng.next_u64(),
        },
    }
}

fn rand_instr(rng: &mut Xoshiro256) -> Instruction {
    if rng.chance(0.25) {
        rand_program(rng)
    } else {
        rand_simple_instr(rng)
    }
}

#[test]
fn instruction_round_trips_and_rejects_truncation() {
    prop::check(|rng, _case| {
        let instr = rand_instr(rng);
        let flags = rand_flags(rng);
        let mut w = Writer::default();
        instr.encode(flags, &mut w);
        let bytes = w.into_vec();
        let mut r = Reader::new(&bytes);
        let (back, back_flags) = Instruction::decode(&mut r).unwrap();
        assert_eq!(back, instr);
        assert_eq!(back_flags, flags);
        assert_eq!(r.remaining(), 0, "codec must consume exactly its bytes");
        // Every strict prefix must be rejected, never mis-parsed.
        for cut in 0..bytes.len() {
            let mut r = Reader::new(&bytes[..cut]);
            assert!(Instruction::decode(&mut r).is_err(), "cut={cut}");
        }
    });
}

fn rand_packet(rng: &mut Xoshiro256) -> Packet {
    let n_segs = rng.next_below(4) + 1;
    let segs: Vec<Segment> = (0..n_segs)
        .map(|i| {
            Segment::call(
                DeviceIp::lan((10 + i) as u8),
                (rng.next_u64() & 0xFFFF) as u16,
            )
        })
        .collect();
    let payload_len = prop::log_size(rng, 64);
    let payload: Vec<u8> = (0..payload_len)
        .map(|_| (rng.next_u64() & 0xFF) as u8)
        .collect();
    Packet::new(
        DeviceIp::lan(1),
        rng.next_u64(),
        SrouHeader::through(segs),
        rand_instr(rng),
    )
    .with_flags(rand_flags(rng))
    .with_payload(Payload::from_bytes(payload))
}

#[test]
fn packet_round_trips_and_rejects_truncation() {
    prop::check(|rng, case| {
        let pkt = rand_packet(rng);
        let bytes = pkt.encode().unwrap();
        let back = Packet::decode(&bytes).unwrap();
        assert_eq!(back, pkt, "case {case}");
        // Truncations: check all short cuts cheaply, plus random cuts.
        for cut in 0..bytes.len().min(48) {
            assert!(Packet::decode(&bytes[..cut]).is_err(), "cut={cut}");
        }
        for _ in 0..16 {
            let cut = rng.next_below(bytes.len() as u64) as usize;
            assert!(Packet::decode(&bytes[..cut]).is_err(), "cut={cut}");
        }
        // Trailing garbage is rejected too.
        let mut longer = bytes.clone();
        longer.push(0);
        assert!(Packet::decode(&longer).is_err());
    });
}

#[test]
fn verified_ring_programs_match_their_srou_budget() {
    prop::check(|rng, _case| {
        let ranks = (rng.next_below(7) + 2) as usize; // 2..=8
        let fused = rng.chance(0.5);
        let hops = if fused { 2 * (ranks - 1) } else { ranks - 1 };
        let env = VerifyEnv {
            capacity: 1 << 20,
            payload_len: 1024,
            ordered: false,
            lossless: rng.chance(0.5),
            srou_hops: hops,
            registry: None,
        };
        let mut b = ProgramBuilder::new()
            .reduce(SimdOp::Add, 0x2000, (ranks - 1) as u8)
            .guarded_write(0x2000, rng.next_u64());
        if fused {
            b = b.store(0x2000, (ranks - 1) as u8);
        }
        let p = b.on_retire(1).build(&env).expect("safe ring chain verifies");
        assert_eq!(p.hops(), hops);
        assert!(p.idempotent());
        // The same chain with a non-commutative op must be rejected on
        // this (unordered) path.
        let err = ProgramBuilder::new()
            .reduce(SimdOp::Sub, 0x2000, (ranks - 1) as u8)
            .guarded_write(0x2000, 0)
            .build(&VerifyEnv {
                srou_hops: ranks - 1,
                ..env
            })
            .unwrap_err();
        assert!(
            matches!(err, netdam::isa::ProgramError::NonCommutativeReduce { .. }),
            "{err}"
        );
    });
}
