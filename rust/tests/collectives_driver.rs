//! Driver-level acceptance tests: every algorithm in the menu runs
//! through the shared `collectives::driver`, produces a report on the
//! same grid, and — in data-payload mode — lands bit-exactly on the
//! oracle, on lossless *and* lossy fabrics.

use netdam::collectives::{
    naive_sum, read_vector, run_collective, seed_gradients_exact, AlgoKind, CollectiveSpec,
    Driver, HalvingDoubling, HierarchicalAllreduce, RingAllreduce, RunOpts,
};
use netdam::net::{Cluster, EcmpMode, LinkConfig, Topology};
use netdam::sim::Engine;

/// Allreduce algorithms that leave every device holding the exact sum.
/// Exact integer seeding makes the oracle order-free, so the ring, the
/// halving-doubling exchange order, and the two-level hierarchy must all
/// match `naive_sum` bit-for-bit (§3's Data-payload claim).
fn verify_allreduce(kind: AlgoKind, ranks: usize, elements: usize, loss_p: f64, reliable: bool) {
    let (topo, groups) = if kind == AlgoKind::Hierarchical {
        let t = Topology::fat_tree(
            0xA5,
            2,
            ranks / 2,
            2,
            LinkConfig::dc_100g(),
            EcmpMode::FlowHash,
        );
        let g = t.leaf_groups.clone();
        (t, g)
    } else {
        let t = Topology::star(0xA5, ranks, 0, LinkConfig::dc_100g());
        let g = t.leaf_groups.clone();
        (t, g)
    };
    let mut cl = topo.cluster;
    let devices = topo.devices;
    cl.fault.loss_p = loss_p;
    let grads = seed_gradients_exact(&mut cl, &devices, elements, 0, 0x77);
    let spec = CollectiveSpec {
        elements,
        window: 4,
        reliable,
        ..Default::default()
    };
    let mut eng: Engine<Cluster> = Engine::new();
    let out = match kind {
        AlgoKind::NetdamRing => {
            let mut algo = RingAllreduce { fused: true };
            Driver::run(&mut cl, &mut eng, &devices, &mut algo, &spec).unwrap()
        }
        AlgoKind::HalvingDoubling => {
            let mut algo = HalvingDoubling::new(ranks).unwrap();
            Driver::run(&mut cl, &mut eng, &devices, &mut algo, &spec).unwrap()
        }
        AlgoKind::Hierarchical => {
            let mut algo = HierarchicalAllreduce::new(groups).unwrap();
            Driver::run(&mut cl, &mut eng, &devices, &mut algo, &spec).unwrap()
        }
        other => panic!("not an allreduce: {other:?}"),
    };
    assert_eq!(
        out.ops_done, out.ops,
        "{kind:?} incomplete (loss_p={loss_p}, reliable={reliable})"
    );
    if loss_p > 0.0 {
        assert!(out.retransmits > 0, "{kind:?}: loss must trigger retries");
    }
    let oracle = naive_sum(&grads);
    for &d in &devices {
        assert_eq!(
            read_vector(&mut cl, d, 0, elements).unwrap(),
            oracle,
            "{kind:?} diverged (loss_p={loss_p})"
        );
    }
}

#[test]
fn ring_matches_oracle_lossless_and_lossy() {
    verify_allreduce(AlgoKind::NetdamRing, 4, 4 * 2048 * 2, 0.0, false);
    verify_allreduce(AlgoKind::NetdamRing, 4, 4 * 2048 * 2, 0.01, true);
}

#[test]
fn halving_doubling_matches_oracle_lossless_and_lossy() {
    verify_allreduce(AlgoKind::HalvingDoubling, 4, 4 * 2048 * 2, 0.0, false);
    verify_allreduce(AlgoKind::HalvingDoubling, 4, 4 * 2048 * 2, 0.01, true);
}

#[test]
fn hierarchical_matches_oracle_lossless_and_lossy() {
    verify_allreduce(AlgoKind::Hierarchical, 4, 2 * 2048 * 2, 0.0, false);
    verify_allreduce(AlgoKind::Hierarchical, 4, 2 * 2048 * 2, 0.01, true);
}

#[test]
fn all_allreduces_agree_with_each_other() {
    // Same data, three algorithms, one answer — the driver refactor's
    // contract in a single assertion.
    let ranks = 4;
    let elements = 4 * 2048;
    let mut images: Vec<Vec<f32>> = Vec::new();
    for kind in [
        AlgoKind::NetdamRing,
        AlgoKind::HalvingDoubling,
        AlgoKind::Hierarchical,
    ] {
        let (topo, groups) = if kind == AlgoKind::Hierarchical {
            let t = Topology::fat_tree(
                3,
                2,
                ranks / 2,
                2,
                LinkConfig::dc_100g(),
                EcmpMode::FlowHash,
            );
            let g = t.leaf_groups.clone();
            (t, g)
        } else {
            let t = Topology::star(3, ranks, 0, LinkConfig::dc_100g());
            let g = t.leaf_groups.clone();
            (t, g)
        };
        let mut cl = topo.cluster;
        let devices = topo.devices;
        seed_gradients_exact(&mut cl, &devices, elements, 0, 0xF00D);
        let spec = CollectiveSpec {
            elements,
            window: 8,
            ..Default::default()
        };
        let mut eng: Engine<Cluster> = Engine::new();
        let out = match kind {
            AlgoKind::NetdamRing => {
                let mut a = RingAllreduce { fused: true };
                Driver::run(&mut cl, &mut eng, &devices, &mut a, &spec).unwrap()
            }
            AlgoKind::HalvingDoubling => {
                let mut a = HalvingDoubling::new(ranks).unwrap();
                Driver::run(&mut cl, &mut eng, &devices, &mut a, &spec).unwrap()
            }
            _ => {
                let mut a = HierarchicalAllreduce::new(groups).unwrap();
                Driver::run(&mut cl, &mut eng, &devices, &mut a, &spec).unwrap()
            }
        };
        assert_eq!(out.ops_done, out.ops);
        images.push(read_vector(&mut cl, devices[0], 0, elements).unwrap());
    }
    assert_eq!(images[0], images[1], "ring vs halving-doubling");
    assert_eq!(images[0], images[2], "ring vs hierarchical");
}

#[test]
fn whole_menu_reports_on_one_grid() {
    // The bench-facing front door: every algorithm, one call shape, one
    // report type — including the host baselines.
    for kind in AlgoKind::ALL {
        let r = run_collective(
            kind,
            &RunOpts {
                elements: 4 * 2048,
                ranks: 4,
                seed: 0xBE,
                window: 8,
                timing_only: !kind.is_host_baseline(),
                ..Default::default()
            },
        )
        .unwrap();
        assert!(r.elapsed_ns > 0, "{kind:?} reported no elapsed time");
        assert_eq!(r.elements, 4 * 2048);
        assert_eq!(r.algorithm, kind.name());
    }
}

#[test]
fn latency_ordering_small_vs_large() {
    // Halving-doubling wins at small sizes (2·log₂N rounds vs 2·(N−1)
    // serialized chain hops); the ring's bandwidth optimality shows at
    // large sizes where both are wire-bound. At minimum the small-message
    // advantage must hold on the timing model.
    let run = |kind: AlgoKind, elements: usize| {
        run_collective(
            kind,
            &RunOpts {
                elements,
                ranks: 8,
                seed: 0x1A,
                window: 32,
                timing_only: true,
                ..Default::default()
            },
        )
        .unwrap()
        .elapsed_ns
    };
    let small = 8 * 2048; // one block per rank chunk
    let hd = run(AlgoKind::HalvingDoubling, small);
    let ring = run(AlgoKind::NetdamRing, small);
    assert!(
        hd < ring,
        "halving-doubling must win small messages: hd={hd} ring={ring}"
    );
}

#[test]
fn run_collective_rejects_bad_shapes() {
    // Halving-doubling needs 2^k ranks; hierarchical needs an even count.
    assert!(run_collective(
        AlgoKind::HalvingDoubling,
        &RunOpts {
            ranks: 6,
            elements: 6 * 2048,
            timing_only: true,
            ..Default::default()
        }
    )
    .is_err());
    assert!(run_collective(
        AlgoKind::Hierarchical,
        &RunOpts {
            ranks: 5,
            elements: 5 * 2048,
            timing_only: true,
            ..Default::default()
        }
    )
    .is_err());
}
