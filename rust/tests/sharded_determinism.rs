//! Determinism proofs for the sharded parallel DES core: the same seed
//! must produce **bit-identical** results at any shard count and thread
//! count — including under loss, where the per-link RNG partitioning is
//! doing the heavy lifting — and the classic single-heap engine must
//! stay report-compatible with the single-shard partitioned run on a
//! loss-free fabric.

use netdam::collectives::{naive_sum, AlgoKind, CollectiveReport};
use netdam::comm::Fabric;
use netdam::net::{Node, ShardPartition};

/// A lossy, reliable ring allreduce on the 2-pod fat-tree, driven
/// through the sharded core. Returns the bench-facing report plus every
/// rank's final vector.
fn lossy_fat_tree_run(shards: usize, threads: usize) -> (CollectiveReport, Vec<Vec<f32>>) {
    let elements = 8 * 512;
    let mut f = Fabric::builder()
        .fat_tree(2, 4, 2)
        .seed(0xD15C)
        .reliable(true)
        .loss(0.05)
        .window(4)
        .with_shards(shards)
        .shard_threads(threads)
        .build()
        .unwrap();
    let comm = f.communicator(elements as u64 * 4).unwrap();
    let grads = comm.seed_gradients_exact(&mut f, elements, 0x5EED);
    let h = comm.iallreduce(&mut f, elements).unwrap();
    let out = f.wait(h).unwrap();
    assert!(
        out.complete(),
        "shards={shards}: {}/{} ops",
        out.ops_done,
        out.ops
    );
    let report = f.report(&out);
    let oracle = naive_sum(&grads);
    let mut vecs = Vec::with_capacity(f.ranks());
    for r in 0..f.ranks() {
        let v = comm.read_vector(&mut f, r, elements).unwrap();
        assert_eq!(v, oracle, "shards={shards}: rank {r} diverged from oracle");
        vecs.push(v);
    }
    assert!(f.sharded_events() > 0, "the sharded core actually ran");
    (report, vecs)
}

/// Same seed ⇒ bit-identical `CollectiveReport` (and per-rank data) at
/// shard counts 1, 2 and 4 — with loss and retransmits in play.
#[test]
fn lossy_allreduce_reports_identical_across_shard_counts() {
    let (r1, v1) = lossy_fat_tree_run(1, 1);
    let (r2, v2) = lossy_fat_tree_run(2, 1);
    let (r4, v4) = lossy_fat_tree_run(4, 1);
    assert!(r1.link_drops > 0, "the loss model never fired: {r1:?}");
    assert!(r1.retransmits > 0, "loss recovered without retransmits?");
    assert_eq!(r1, r2, "1 vs 2 shards");
    assert_eq!(r1, r4, "1 vs 4 shards");
    assert_eq!(v1, v2);
    assert_eq!(v1, v4);
}

/// Thread count is an execution detail, not a semantic knob: serial and
/// threaded runs of the same partition are bit-identical, and a repeated
/// run reproduces itself exactly.
#[test]
fn lossy_allreduce_invariant_to_threads_and_repetition() {
    let (serial, vs) = lossy_fat_tree_run(4, 1);
    let (threaded, vt) = lossy_fat_tree_run(4, 2);
    let (again, va) = lossy_fat_tree_run(4, 1);
    assert_eq!(serial, threaded, "1 vs 2 worker threads");
    assert_eq!(serial, again, "repeat run");
    assert_eq!(vs, vt);
    assert_eq!(vs, va);
}

/// Loss-free, the classic single-heap engine and the single-shard
/// partitioned core agree at the report level: same elapsed time, same
/// (zero) drop and retransmit counters, same data. (Under loss the two
/// draw from different RNG stream layouts by design — cross-shard-count
/// comparisons above are the lossy determinism proof.)
#[test]
fn classic_engine_and_single_shard_core_agree_loss_free() {
    let run = |shards: usize| -> (CollectiveReport, Vec<f32>) {
        let elements = 4 * 1024;
        let mut f = Fabric::builder()
            .star(4)
            .seed(0xACE)
            .with_shards(shards) // 0 = classic single-heap engine
            .build()
            .unwrap();
        let comm = f.communicator(elements as u64 * 4).unwrap();
        let grads = comm.seed_gradients_exact(&mut f, elements, 0xE);
        let h = comm.iallreduce(&mut f, elements).unwrap();
        let out = f.wait(h).unwrap();
        assert!(out.complete());
        let v = comm.read_vector(&mut f, 0, elements).unwrap();
        assert_eq!(v, naive_sum(&grads));
        (f.report(&out), v)
    };
    let (classic, vc) = run(0);
    let (sharded, vs) = run(1);
    assert_eq!(classic, sharded, "classic vs with_shards(1)");
    assert_eq!(vc, vs);
}

/// A pooled-memory batch (write, scatter-gather read, CAS) through the
/// shared session on a lossy fabric: bit-identical `BatchResult`, final
/// clock, and retransmit count at shard counts 1, 2 and 4.
#[test]
fn pooled_mem_batch_identical_across_shard_counts() {
    let data: Vec<u8> = (0..64 << 10).map(|i| (i * 37 % 251) as u8).collect();
    let run = |shards: usize| {
        let mut f = Fabric::builder()
            .star(4)
            .hosts(1)
            .seed(0x3E3)
            .reliable(true)
            .loss(0.02)
            .window(4)
            .with_pool(1 << 20)
            .with_shards(shards)
            .shard_threads(1)
            .build()
            .unwrap();
        let client = f.mem_client().unwrap();
        let lease = f.malloc(client.tenant, 64 << 10, true).unwrap();
        let scratch = f.malloc(client.tenant, 8192, true).unwrap();
        f.mem_write(&client, lease.gva, &data).unwrap();
        let mut b = client.batch();
        let hr = b.read(f.cluster_mut(), lease.gva, 32 << 10);
        let hc = b.cas(f.cluster_mut(), scratch.gva, 0, 99).unwrap();
        let h = f.submit_mem(b).unwrap();
        let mut res = f.wait_mem(h).unwrap();
        assert_eq!(
            res.cas_outcome(hc),
            Some((0, true)),
            "shards={shards}: CAS must win on the zeroed scratch word"
        );
        let end = f.now();
        let retransmits = f.cluster().xport.retransmits;
        let got = res.take_read(hr).unwrap();
        assert_eq!(got, data[..32 << 10], "shards={shards}: read-back");
        (got, end, retransmits)
    };
    let r1 = run(1);
    let r2 = run(2);
    let r4 = run(4);
    assert!(r1.2 > 0, "the lossy sweep never exercised a retransmit");
    assert_eq!(r1, r2, "1 vs 2 shards");
    assert_eq!(r1, r4, "1 vs 4 shards");
}

/// A lossy switch-reduce allreduce (in-network aggregation) on the
/// 2-pod fat-tree through the sharded core, with the shard partition a
/// parameter. Returns the report, every rank's final vector, and the
/// fabric-wide count of in-switch merges.
fn lossy_switch_reduce_run(
    shards: usize,
    partition: ShardPartition,
) -> (CollectiveReport, Vec<Vec<f32>>, u64) {
    let elements = 8 * 512;
    let mut f = Fabric::builder()
        .fat_tree(2, 4, 2)
        .seed(0xA66)
        .reliable(true)
        .loss(0.05)
        .window(4)
        .with_shards(shards)
        .shard_threads(1)
        .shard_partition(partition)
        .build()
        .unwrap();
    let comm = f.communicator(elements as u64 * 4).unwrap();
    let grads = comm.seed_gradients_exact(&mut f, elements, 0x566D);
    let h = comm
        .icollective(&mut f, AlgoKind::SwitchReduce, elements, 0)
        .unwrap();
    let out = f.wait(h).unwrap();
    assert!(
        out.complete(),
        "shards={shards}: {}/{} ops",
        out.ops_done,
        out.ops
    );
    let report = f.report(&out);
    let oracle = naive_sum(&grads);
    let mut vecs = Vec::with_capacity(f.ranks());
    for r in 0..f.ranks() {
        let v = comm.read_vector(&mut f, r, elements).unwrap();
        assert_eq!(v, oracle, "shards={shards}: rank {r} diverged from oracle");
        vecs.push(v);
    }
    assert!(f.sharded_events() > 0, "the sharded core actually ran");
    let merged: u64 = f
        .cluster()
        .nodes
        .iter()
        .map(|n| match n {
            Node::Switch(s) => s.agg.counters.merged,
            _ => 0,
        })
        .sum();
    (report, vecs, merged)
}

/// In-network aggregation keeps the bit-identical-across-shard-counts
/// guarantee: aggregation slots, timeouts, and straggler fallbacks are
/// all keyed off deterministic DES state, so the report, the data, and
/// even the in-switch merge counters match at shard counts 1, 2 and 4.
#[test]
fn lossy_switch_reduce_identical_across_shard_counts() {
    let (r1, v1, m1) = lossy_switch_reduce_run(1, ShardPartition::Modulo);
    let (r2, v2, m2) = lossy_switch_reduce_run(2, ShardPartition::Modulo);
    let (r4, v4, m4) = lossy_switch_reduce_run(4, ShardPartition::Modulo);
    assert!(r1.link_drops > 0, "the loss model never fired: {r1:?}");
    assert!(m1 > 0, "the switches never aggregated anything");
    assert_eq!(r1, r2, "1 vs 2 shards");
    assert_eq!(r1, r4, "1 vs 4 shards");
    assert_eq!(v1, v2);
    assert_eq!(v1, v4);
    assert_eq!(m1, m2, "merge counters are deterministic state too");
    assert_eq!(m1, m4);
}

/// Shard *placement* is an execution detail like thread count:
/// pod-aligned partitioning (devices + leaf co-sharded per pod) must be
/// bit-identical to the default modulo striping.
#[test]
fn pod_partitioning_is_bit_identical_to_modulo() {
    let (rm, vm, mm) = lossy_switch_reduce_run(2, ShardPartition::Modulo);
    let (rp, vp, mp) = lossy_switch_reduce_run(2, ShardPartition::Pods);
    assert_eq!(rm, rp, "Pods vs Modulo partitioning");
    assert_eq!(vm, vp);
    assert_eq!(mm, mp);
}

/// The scale target: a 1024-rank fat-tree allreduce completes through
/// the sharded core (halving-doubling: log₂ N phases keeps the debug
/// build fast; the `sim` bench runs the full ring at this scale).
#[test]
fn allreduce_1024_ranks_completes_through_the_sharded_core() {
    let ranks = 1024usize;
    let elements = 2 * ranks;
    let mut f = Fabric::builder()
        .fat_tree(32, 32, 8)
        .timing_only(true)
        .seed(0x400)
        .with_shards(8)
        .build()
        .unwrap();
    assert_eq!(f.ranks(), ranks);
    let comm = f.communicator(elements as u64 * 4).unwrap();
    let h = comm
        .icollective(&mut f, AlgoKind::HalvingDoubling, elements, 0)
        .unwrap();
    let out = f.wait(h).unwrap();
    assert!(out.complete(), "{}/{} ops", out.ops_done, out.ops);
    assert!(out.elapsed_ns() > 0);
    assert!(f.sharded_events() > 0);
}

/// A lossy, reliable ring allreduce with **closed-loop DCQCN active**:
/// tight RED thresholds force CE marks, devices echo them on the
/// completion path, and every CNP mutates a slot controller. Returns the
/// report, the global CE-echo counter, and the full per-slot rate
/// trajectory (`(slot, time, f64 bits)`) so equality means the control
/// loop itself — not just its end state — replayed identically.
fn dcqcn_lossy_run(shards: usize) -> (CollectiveReport, u64, Vec<(usize, u64, u64)>) {
    use netdam::net::LinkConfig;
    use netdam::roce::DcqcnConfig;
    use netdam::transport::CcMode;

    let elements = 8 * 512;
    let mut f = Fabric::builder()
        .fat_tree(2, 4, 2)
        .link(LinkConfig::dc_100g().with_ecn(2_000, 20_000))
        .seed(0xD15C)
        .reliable(true)
        .loss(0.05)
        .window(4)
        .with_congestion_control(CcMode::Dcqcn(DcqcnConfig::default()))
        .with_shards(shards)
        .shard_threads(1)
        .build()
        .unwrap();
    let comm = f.communicator(elements as u64 * 4).unwrap();
    let grads = comm.seed_gradients_exact(&mut f, elements, 0x5EED);
    let h = comm.iallreduce(&mut f, elements).unwrap();
    let out = f.wait(h).unwrap();
    assert!(
        out.complete(),
        "shards={shards}: {}/{} ops",
        out.ops_done,
        out.ops
    );
    let report = f.report(&out);
    let oracle = naive_sum(&grads);
    for r in 0..f.ranks() {
        let v = comm.read_vector(&mut f, r, elements).unwrap();
        assert_eq!(v, oracle, "shards={shards}: rank {r} diverged from oracle");
    }
    let ce = f.cluster().metrics.counter("ecn_ce_received");
    let rate_log = f.rate_log();
    (report, ce, rate_log)
}

/// PR 6's contract survives PR 8: with DCQCN in the loop — RED marks,
/// CE echo, CNPs, multiplicative cuts, timed recovery — the report, the
/// CE counter, and the bit-level rate trajectory of every slot are
/// identical at shard counts 1, 2 and 4 under 5% loss.
#[test]
fn dcqcn_rate_trajectories_identical_across_shard_counts() {
    let (r1, ce1, t1) = dcqcn_lossy_run(1);
    let (r2, ce2, t2) = dcqcn_lossy_run(2);
    let (r4, ce4, t4) = dcqcn_lossy_run(4);
    assert!(ce1 > 0, "no CE marks echoed — the RED ramp never engaged");
    assert!(
        !t1.is_empty(),
        "no rate-controller mutations — DCQCN never absorbed a CNP"
    );
    assert!(r1.link_drops > 0, "the loss model never fired: {r1:?}");
    assert_eq!(r1, r2, "report, 1 vs 2 shards");
    assert_eq!(r1, r4, "report, 1 vs 4 shards");
    assert_eq!(ce1, ce2, "CE echo count, 1 vs 2 shards");
    assert_eq!(ce1, ce4, "CE echo count, 1 vs 4 shards");
    assert_eq!(t1, t2, "rate trajectory, 1 vs 2 shards");
    assert_eq!(t1, t4, "rate trajectory, 1 vs 4 shards");
}
