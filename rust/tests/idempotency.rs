//! E5 — idempotency under faults (paper §3.1).
//!
//! The paper's reliability story: interim reduce-scatter hops have no
//! local side effects, the last hop is guarded by the block hash, so
//! *blind retransmission is always safe*. These tests inject loss,
//! duplication, and both, and demand bit-exact allreduce results.

use netdam::collectives::{oracle_sum, read_vector, run_ring_allreduce, seed_gradients, RingSpec};
use netdam::net::{Cluster, LinkConfig, Topology};
use netdam::sim::Engine;

fn run_with_faults(loss_p: f64, dup_p: f64, reliable: bool, seed: u64) -> (bool, u64, u64) {
    let elements = 4 * 2048 * 4;
    let t = Topology::star(seed, 4, 0, LinkConfig::dc_100g());
    let mut cl = t.cluster;
    cl.fault.loss_p = loss_p;
    cl.fault.dup_p = dup_p;
    let devices = t.devices;
    let grads = seed_gradients(&mut cl, &devices, elements, 0, seed ^ 0x9E);
    let spec = RingSpec {
        elements,
        reliable,
        window: 4,
        ..Default::default()
    };
    let mut eng: Engine<Cluster> = Engine::new();
    let out = run_ring_allreduce(&mut cl, &mut eng, &devices, &spec).unwrap();
    assert_eq!(out.blocks_done, out.blocks, "collective incomplete");
    let oracle = oracle_sum(&grads);
    let mut exact = true;
    for &d in &devices {
        let got = read_vector(&mut cl, d, 0, elements).unwrap();
        exact &= got == oracle;
    }
    (exact, out.retransmits, out.hash_guard_drops)
}

#[test]
fn duplication_alone_cannot_double_add() {
    // 5% duplication, no retransmit machinery: the hash guard at the
    // chunk owner must absorb every duplicate chain.
    let (exact, retx, guard_drops) = run_with_faults(0.0, 0.05, false, 51);
    assert!(exact, "duplicated chains must not double-add");
    assert_eq!(retx, 0);
    assert!(guard_drops > 0, "guard must actually have fired");
}

#[test]
fn loss_with_retransmit_is_exactly_once() {
    let (exact, retx, _) = run_with_faults(0.01, 0.0, true, 52);
    assert!(exact, "retransmitted chains must converge to the exact sum");
    assert!(retx > 0, "1% loss must trigger retransmissions");
}

#[test]
fn loss_and_duplication_together() {
    let (exact, _retx, _) = run_with_faults(0.01, 0.03, true, 53);
    assert!(exact, "combined faults still bit-exact");
}

#[test]
fn fault_free_baseline_no_guard_hits() {
    let (exact, retx, guard_drops) = run_with_faults(0.0, 0.0, false, 54);
    assert!(exact);
    assert_eq!(retx, 0);
    assert_eq!(guard_drops, 0);
}

#[test]
fn results_identical_across_fault_patterns() {
    // The whole point of §3.1: the final memory image is a function of
    // the inputs only, not of the fault pattern (same seed for data).
    let elements = 4 * 2048 * 2;
    let mut images: Vec<Vec<f32>> = Vec::new();
    for (loss, dup, reliable) in [(0.0, 0.0, false), (0.02, 0.0, true), (0.0, 0.04, false)] {
        let t = Topology::star(99, 4, 0, LinkConfig::dc_100g());
        let mut cl = t.cluster;
        cl.fault.loss_p = loss;
        cl.fault.dup_p = dup;
        let devices = t.devices;
        seed_gradients(&mut cl, &devices, elements, 0, 1234);
        let mut eng: Engine<Cluster> = Engine::new();
        let out = run_ring_allreduce(
            &mut cl,
            &mut eng,
            &devices,
            &RingSpec {
                elements,
                reliable,
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(out.blocks_done, out.blocks);
        images.push(read_vector(&mut cl, devices[0], 0, elements).unwrap());
    }
    assert_eq!(images[0], images[1], "loss+retry image matches clean run");
    assert_eq!(images[0], images[2], "duplication image matches clean run");
}
