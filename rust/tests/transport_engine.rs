//! Property tests for the shared windowed transport engine
//! (`transport::engine::WindowEngine`) — the loss sweep the ISSUE asks
//! for: random drop rates × window sizes × both completion-key flavors.
//!
//! Invariants checked on every combination:
//! * every op retires **exactly once** (done == ops, and duplicate
//!   completions from retransmitted chains are ignored);
//! * in-flight ops per slot never exceed the window;
//! * paced mode never releases bytes faster than the token rate;
//! * a drained run leaves no dangling reliability entries and no
//!   completion hook installed.

use netdam::isa::{Flags, Instruction, ProgramBuilder};
use netdam::net::{Cluster, LinkConfig, NodeId, Topology};
use netdam::sim::Engine;
use netdam::transport::{
    CompletionKey, EngineSession, ReliabilityTable, TokenBucket, WindowEngine, WindowedOp,
};
use netdam::wire::{DeviceIp, Packet, Payload, SrouHeader};

/// Seq-keyed ops: reliable WRITEs from one host sprayed round-robin over
/// the pool devices (the MemClient shape).
fn seq_ops(
    cl: &mut Cluster,
    host: NodeId,
    host_ip: DeviceIp,
    devices: &[DeviceIp],
    n: usize,
    payload: usize,
) -> Vec<WindowedOp> {
    (0..n)
        .map(|i| {
            let slot = i % devices.len();
            let seq = cl.alloc_seq(host);
            let pkt = Packet::new(
                host_ip,
                seq,
                SrouHeader::direct(devices[slot]),
                Instruction::Write {
                    addr: (i * payload) as u64,
                },
            )
            .with_flags(Flags(Flags::RELIABLE))
            .with_payload(Payload::from_bytes(vec![i as u8; payload]));
            let pace_bytes = pkt.wire_bytes();
            WindowedOp {
                slot,
                origin: host,
                key: CompletionKey::Seq(seq),
                tag: i as u64,
                reliable: true,
                pace_bytes,
                pkt,
            }
        })
        .collect()
}

/// Done-id-keyed ops: reliable store-chain programs injected from device
/// 0 toward device 1, each retiring with `CollectiveDone { block: i }`
/// back at the origin (the collective-driver shape).
fn done_ops(
    cl: &mut Cluster,
    origin: NodeId,
    origin_ip: DeviceIp,
    target_ip: DeviceIp,
    n: usize,
) -> Vec<WindowedOp> {
    (0..n)
        .map(|i| {
            let seq = cl.alloc_seq(origin);
            let prog = ProgramBuilder::new()
                .store((i * 64) as u64, 1)
                .on_retire(i as u32)
                .build_unchecked();
            let pkt = Packet::new(
                origin_ip,
                seq,
                SrouHeader::direct(target_ip),
                Instruction::Program(std::sync::Arc::new(prog)),
            )
            .with_flags(Flags(Flags::RELIABLE))
            .with_payload(Payload::from_f32s(&[i as f32; 16]));
            let pace_bytes = pkt.wire_bytes();
            WindowedOp {
                slot: 0,
                origin,
                key: CompletionKey::DoneId(i as u32),
                tag: i as u64,
                reliable: true,
                pace_bytes,
                pkt,
            }
        })
        .collect()
}

#[test]
fn loss_sweep_seq_keyed_ops_retire_exactly_once() {
    for &loss in &[0.0f64, 0.1, 0.3] {
        for &window in &[1usize, 2, 8] {
            let t = Topology::star(
                0x7E57 ^ (window as u64) << 8 ^ (loss * 100.0) as u64,
                4,
                1,
                LinkConfig::dc_100g(),
            );
            let mut cl = t.cluster;
            cl.fault.loss_p = loss;
            cl.xport = ReliabilityTable::new(30_000, 64);
            let mut eng: Engine<Cluster> = Engine::new();
            let ips: Vec<DeviceIp> = (1..=4).map(DeviceIp::lan).collect();
            let ops = seq_ops(&mut cl, t.hosts[0], DeviceIp::lan(101), &ips, 40, 256);
            let out = WindowEngine::new(window)
                .run(&mut cl, &mut eng, ops)
                .unwrap();
            assert_eq!(
                out.done, out.ops,
                "loss {loss} window {window}: every op must retire"
            );
            assert!(
                out.max_inflight <= window,
                "loss {loss}: in-flight {} exceeded window {window}",
                out.max_inflight
            );
            assert!(out.nak.is_none());
            assert_eq!(
                cl.xport.outstanding(),
                0,
                "no dangling reliability entries after the run drains"
            );
            assert!(cl.on_completion.is_none(), "hook must be torn down");
            if loss == 0.0 {
                assert_eq!(out.duplicate_completions, 0, "lossless runs see no echoes");
            }
        }
    }
}

#[test]
fn loss_sweep_done_id_ops_retire_exactly_once() {
    for &loss in &[0.0f64, 0.1, 0.3] {
        for &window in &[1usize, 4] {
            let t = Topology::star(
                0xD0E ^ (window as u64) << 4 ^ (loss * 100.0) as u64,
                2,
                0,
                LinkConfig::dc_100g(),
            );
            let mut cl = t.cluster;
            cl.fault.loss_p = loss;
            cl.xport = ReliabilityTable::new(30_000, 64);
            let mut eng: Engine<Cluster> = Engine::new();
            let ops = done_ops(
                &mut cl,
                t.devices[0],
                DeviceIp::lan(1),
                DeviceIp::lan(2),
                24,
            );
            let out = WindowEngine::new(window)
                .run(&mut cl, &mut eng, ops)
                .unwrap();
            assert_eq!(
                out.done, out.ops,
                "loss {loss} window {window}: every chain must retire"
            );
            assert!(out.max_inflight <= window);
            assert_eq!(cl.xport.outstanding(), 0);
            assert!(cl.on_completion.is_none());
        }
    }
}

#[test]
fn paced_mode_never_exceeds_the_token_rate() {
    let t = Topology::star(0xACED, 4, 1, LinkConfig::dc_100g());
    let mut cl = t.cluster;
    let mut eng: Engine<Cluster> = Engine::new();
    let ips: Vec<DeviceIp> = (1..=4).map(DeviceIp::lan).collect();
    let ops = seq_ops(&mut cl, t.hosts[0], DeviceIp::lan(101), &ips, 48, 1024);
    // 8 Gbps = 1 B/ns, 4 KiB burst.
    let (rate_bpns, burst) = (1.0f64, 4096usize);
    let out = WindowEngine::new(8)
        .paced(TokenBucket::new(8.0, burst))
        .run(&mut cl, &mut eng, ops)
        .unwrap();
    assert_eq!(out.done, out.ops);
    assert!(!out.releases.is_empty(), "paced runs log their releases");
    let mut releases = out.releases.clone();
    releases.sort_unstable();
    let mut cum = 0usize;
    for &(at, bytes) in &releases {
        cum += bytes;
        assert!(
            cum as f64 <= burst as f64 + rate_bpns * at as f64 + 2.0,
            "released {cum} B by t={at} ns — exceeds burst + rate·t"
        );
    }
    // Pacing actually throttled (not everything fit in the burst).
    assert!(
        releases.iter().any(|&(at, _)| at > 0),
        "a 48 KiB plan must overrun a 4 KiB burst"
    );
    // Windowing still bounds the in-flight count under pacing.
    assert!(out.max_inflight <= 8);
}

/// Per-slot pacing gives every destination its own bucket: each slot's
/// release log respects its bucket envelope, while the aggregate across
/// slots exceeds what one shared bucket would ever release — fan-out is
/// no longer serialized behind a single pacer (the ROADMAP per-slot
/// item).
#[test]
fn per_slot_pacing_paces_each_destination_independently() {
    let t = Topology::star(0x51A7, 4, 1, LinkConfig::dc_100g());
    let mut cl = t.cluster;
    let mut eng: Engine<Cluster> = Engine::new();
    let ips: Vec<DeviceIp> = (1..=4).map(DeviceIp::lan).collect();
    let ops = seq_ops(&mut cl, t.hosts[0], DeviceIp::lan(101), &ips, 64, 1024);
    // 8 Gbps = 1 B/ns per destination, 2 KiB burst each.
    let (rate_bpns, burst) = (1.0f64, 2048usize);
    let out = WindowEngine::new(8)
        .paced_per_slot(TokenBucket::new(8.0, burst))
        .run(&mut cl, &mut eng, ops)
        .unwrap();
    assert_eq!(out.done, out.ops);
    assert!(!out.releases_per_slot.is_empty());
    // Per-slot bound: cumulative bytes ≤ burst + rate·t for every slot.
    for slot in 0..4 {
        let mut rel: Vec<(u64, usize)> = out
            .releases_per_slot
            .iter()
            .filter(|&&(s, _, _)| s == slot)
            .map(|&(_, at, b)| (at, b))
            .collect();
        assert!(!rel.is_empty(), "slot {slot} released nothing");
        rel.sort_unstable();
        let mut cum = 0usize;
        for &(at, bytes) in &rel {
            cum += bytes;
            assert!(
                cum as f64 <= burst as f64 + rate_bpns * at as f64 + 2.0,
                "slot {slot}: {cum} B by t={at} ns exceeds its bucket"
            );
        }
    }
    // Aggregate proof of independence: at some instant the fleet has
    // released more than one shared bucket could have.
    let mut all: Vec<(u64, usize)> = out
        .releases_per_slot
        .iter()
        .map(|&(_, at, b)| (at, b))
        .collect();
    all.sort_unstable();
    let mut cum = 0usize;
    let mut exceeded = false;
    for &(at, bytes) in &all {
        cum += bytes;
        if cum as f64 > burst as f64 + rate_bpns * at as f64 + 2.0 {
            exceeded = true;
            break;
        }
    }
    assert!(
        exceeded,
        "4 destinations never beat a single bucket's envelope — pacing is still global"
    );
    // Pacing actually deferred something.
    assert!(out.releases_per_slot.iter().any(|&(_, at, _)| at > 0));
}

/// Two plans on one session: submitted incrementally, in flight
/// together, retired independently, with per-plan outcomes.
#[test]
fn session_multiplexes_two_plans() {
    let t = Topology::star(0x5E55, 4, 1, LinkConfig::dc_100g());
    let mut cl = t.cluster;
    let mut eng: Engine<Cluster> = Engine::new();
    let ips: Vec<DeviceIp> = (1..=4).map(DeviceIp::lan).collect();
    let ops_a = seq_ops(&mut cl, t.hosts[0], DeviceIp::lan(101), &ips, 12, 256);
    let ops_b = done_ops(&mut cl, t.devices[0], ips[0], ips[1], 6);
    let mut session = EngineSession::new(4);
    let pa = session.submit(&mut cl, &mut eng, ops_a, false, 4).unwrap();
    let pb = session.submit(&mut cl, &mut eng, ops_b, false, 4).unwrap();
    assert!(!session.is_complete(pa) && !session.is_complete(pb));
    session.drive(&mut cl, &mut eng);
    assert!(session.is_complete(pa) && session.is_complete(pb));
    assert!(
        session.max_concurrent_plans() >= 2,
        "plans never coexisted in flight"
    );
    let oa = session.outcome(pa);
    let ob = session.outcome(pb);
    assert_eq!((oa.done, oa.ops), (12, 12));
    assert_eq!((ob.done, ob.ops), (6, 6));
    assert!(oa.nak.is_none() && ob.nak.is_none());
    session.close(&mut cl);
    assert!(cl.on_completion.is_none(), "hook torn down");
    assert_eq!(cl.xport.outstanding(), 0);
}

/// Mixed key flavors in one run: the engine retires each with the right
/// matcher (seq ops by response sequence, chain ops by done-id).
#[test]
fn mixed_key_flavors_coexist() {
    let t = Topology::star(0x313D, 4, 1, LinkConfig::dc_100g());
    let mut cl = t.cluster;
    let mut eng: Engine<Cluster> = Engine::new();
    let ips: Vec<DeviceIp> = (1..=4).map(DeviceIp::lan).collect();
    let mut ops = seq_ops(&mut cl, t.hosts[0], DeviceIp::lan(101), &ips, 12, 128);
    // Chain ops from device 0 → device 1 on their own slot (4).
    let mut chains = done_ops(&mut cl, t.devices[0], ips[0], ips[1], 6);
    for c in &mut chains {
        c.slot = 4;
    }
    ops.extend(chains);
    let out = WindowEngine::new(4)
        .record_responses(true)
        .run(&mut cl, &mut eng, ops)
        .unwrap();
    assert_eq!(out.done, out.ops);
    // Recorded responses cover both flavors.
    let dones = out
        .responses
        .iter()
        .filter(|r| matches!(r.instr, Instruction::CollectiveDone { .. }))
        .count();
    let acks = out
        .responses
        .iter()
        .filter(|r| matches!(r.instr, Instruction::WriteAck { .. }))
        .count();
    assert_eq!(dones, 6);
    assert_eq!(acks, 12);
}

/// Memory-compaction regression: a long-lived session that submits,
/// drains, and releases plans sequentially must not accumulate per-plan
/// bookkeeping — the slab stays at one slot (recycled every round) and
/// the slot space stays bounded by concurrency, not history.
#[test]
fn released_plans_recycle_slab_slots() {
    let t = Topology::star(0xC0DE, 4, 1, LinkConfig::dc_100g());
    let mut cl = t.cluster;
    let mut eng: Engine<Cluster> = Engine::new();
    let ips: Vec<DeviceIp> = (1..=4).map(DeviceIp::lan).collect();
    let mut session = EngineSession::new(4);
    for round in 0..60 {
        let ops = seq_ops(&mut cl, t.hosts[0], DeviceIp::lan(101), &ips, 8, 128);
        let plan = session.submit(&mut cl, &mut eng, ops, false, 4).unwrap();
        session.drive(&mut cl, &mut eng);
        assert!(session.is_complete(plan), "round {round} drained");
        let out = session.outcome(plan);
        assert_eq!(out.done, 8);
        session.release(plan).unwrap();
        assert_eq!(session.live_plans(), 0, "round {round}: nothing live");
    }
    assert_eq!(
        session.plan_slab_len(),
        1,
        "60 sequential plans must reuse one slab slot"
    );
    session.close(&mut cl);
}

/// `release` refuses unsettled plans and stale (already released) ids.
#[test]
fn release_refuses_unsettled_and_stale_ids() {
    let t = Topology::star(0xF00D, 2, 1, LinkConfig::dc_100g());
    let mut cl = t.cluster;
    let mut eng: Engine<Cluster> = Engine::new();
    let mut session = EngineSession::new(2);
    let ops = seq_ops(
        &mut cl,
        t.hosts[0],
        DeviceIp::lan(101),
        &[DeviceIp::lan(1)],
        4,
        64,
    );
    let plan = session.submit(&mut cl, &mut eng, ops, false, 2).unwrap();
    // Not driven yet: ops are queued/in flight, so release must refuse.
    assert!(session.release(plan).is_err(), "unsettled plan released");
    session.drive(&mut cl, &mut eng);
    assert!(session.is_settled(plan));
    session.release(plan).unwrap();
    // Second release sees a stale id.
    assert!(session.release(plan).is_err(), "stale id released twice");
    session.close(&mut cl);
}

/// A plan-private pacer throttles its own plan and nobody else: the paced
/// plan's release log obeys its bucket while an unpaced plan on the same
/// session flows freely.
#[test]
fn plan_private_pacer_rides_an_unpaced_session() {
    let t = Topology::star(0xBEEF, 4, 1, LinkConfig::dc_100g());
    let mut cl = t.cluster;
    let mut eng: Engine<Cluster> = Engine::new();
    let ips: Vec<DeviceIp> = (1..=4).map(DeviceIp::lan).collect();
    let paced_ops = seq_ops(&mut cl, t.hosts[0], DeviceIp::lan(101), &ips, 32, 1024);
    let free_ops = done_ops(&mut cl, t.devices[0], ips[0], ips[1], 6);
    let mut session = EngineSession::new(8);
    // 8 Gbps = 1 B/ns, 4 KiB burst — 32 KiB of paced ops must spill
    // past the burst and get deferred releases.
    let (rate_bpns, burst) = (1.0f64, 4096usize);
    let paced = session
        .submit_paced(
            &mut cl,
            &mut eng,
            paced_ops,
            false,
            8,
            TokenBucket::new(8.0, burst),
        )
        .unwrap();
    let free = session.submit(&mut cl, &mut eng, free_ops, false, 8).unwrap();
    session.drive(&mut cl, &mut eng);
    assert!(session.is_complete(paced) && session.is_complete(free));
    let releases = session.releases();
    assert!(
        !releases.is_empty(),
        "paced plan must log its bucket releases"
    );
    let mut rel: Vec<(u64, usize)> = releases.iter().map(|&(_, at, b)| (at, b)).collect();
    rel.sort_unstable();
    let mut cum = 0usize;
    for &(at, bytes) in &rel {
        cum += bytes;
        assert!(
            cum as f64 <= burst as f64 + rate_bpns * at as f64 + 2.0,
            "paced plan exceeded its private bucket: {cum} B by t={at}"
        );
    }
    assert!(
        rel.iter().any(|&(at, _)| at > 0),
        "32 KiB must overrun a 4 KiB burst"
    );
    session.close(&mut cl);
}

/// Property: across ANY schedule of `set_rate` retargets (applied at
/// settled instants, i.e. after the bucket's committed debt has been
/// released — exactly when a rate change can still bind every future
/// release), the cumulative bytes released by time `t` never exceed
/// `burst + ∫rate(τ)dτ` over `[0, t]`. This is the actuator contract
/// DCQCN leans on: a multiplicative cut takes effect at fill-rate
/// granularity, and a recovery ramp can never mint tokens
/// retroactively.
#[test]
fn set_rate_preserves_the_integral_rate_envelope() {
    use netdam::util::SplitMix64;

    let rates_gbps = [0.8f64, 4.0, 8.0, 40.0, 100.0];
    for seed in 0..8u64 {
        let mut rng = SplitMix64::new(0x5E7_2A7E ^ seed);
        let burst = 4096usize;
        let mut tb = TokenBucket::new(8.0, burst);
        // Piecewise-constant rate schedule: (from_ns, bytes-per-ns).
        let mut segments: Vec<(u64, f64)> = vec![(0, 1.0)];
        let mut releases: Vec<(u64, usize)> = Vec::new();
        let mut now = 0u64;
        let mut last_release = 0u64;
        for _ in 0..300 {
            let r = rng.next_u64();
            now += r % 500;
            if r % 7 == 0 {
                // Retarget at a settled instant so the new rate governs
                // every byte not yet released.
                now = now.max(last_release);
                let g = rates_gbps[(r / 7) as usize % rates_gbps.len()];
                tb.set_rate(now, g);
                segments.push((now, g / 8.0));
            } else {
                let bytes = 64 + (r / 11) as usize % 4032;
                let at = tb.reserve(now, bytes);
                assert!(at >= now, "release {at} precedes its reservation {now}");
                assert!(
                    at >= last_release,
                    "bucket releases must stay monotonic: {at} < {last_release}"
                );
                last_release = at;
                releases.push((at, bytes));
            }
        }
        // ∫rate over [0, t] under the piecewise schedule (the last
        // segment extends past the final retarget).
        let integral = |t: u64| -> f64 {
            let mut acc = 0.0;
            for (i, &(from, bpns)) in segments.iter().enumerate() {
                if from >= t {
                    break;
                }
                let to = segments.get(i + 1).map_or(t, |&(f, _)| f.min(t));
                acc += (to - from) as f64 * bpns;
            }
            acc
        };
        let mut cum = 0usize;
        for &(at, bytes) in &releases {
            cum += bytes;
            assert!(
                cum as f64 <= burst as f64 + integral(at) + 2.0,
                "seed {seed}: released {cum} B by t={at} ns — exceeds \
                 burst + ∫rate(t)dt = {:.1}",
                burst as f64 + integral(at)
            );
        }
        // The schedule actually exercised both halves: some retargets
        // happened and pacing deferred at least one release.
        assert!(segments.len() > 1, "seed {seed}: no rate changes drawn");
        assert!(
            releases.iter().any(|&(at, _)| at > 0),
            "seed {seed}: nothing was ever paced"
        );
    }
}
