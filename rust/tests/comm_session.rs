//! Integration tests for the session API (`netdam::comm`): multi-tenant
//! fabrics, nonblocking collectives, gradient bucketing, and per-plan
//! NAK isolation on the shared window engine.

use netdam::collectives::naive_sum;
use netdam::comm::{buckets_total_elems, plan_buckets, Fabric, GradBucket};
use netdam::mem::MemError;

/// Two tenants' allreduces interleave on ONE fabric: in-flight ops from
/// both coexist on the shared engine, and both match the host oracle.
#[test]
fn two_tenants_interleave_allreduces_on_one_fabric() {
    let elements = 4 * 2048;
    let mut f = Fabric::builder().star(4).seed(0x2B).build().unwrap();
    let job_a = f.communicator(elements as u64 * 4).unwrap();
    let job_b = f.communicator(elements as u64 * 4).unwrap();
    let ga = job_a.seed_gradients_exact(&mut f, elements, 0xA11);
    let gb = job_b.seed_gradients_exact(&mut f, elements, 0xB22);

    // Both submitted before either completes — genuinely nonblocking.
    let ha = job_a.iallreduce(&mut f, elements).unwrap();
    let hb = job_b.iallreduce(&mut f, elements).unwrap();
    assert!(!f.is_finished(ha) && !f.is_finished(hb));
    let oa = f.wait(ha).unwrap();
    let ob = f.wait(hb).unwrap();
    assert!(oa.complete(), "job A: {}/{}", oa.ops_done, oa.ops);
    assert!(ob.complete(), "job B: {}/{}", ob.ops_done, ob.ops);

    // The tenants shared the engine: both plans were in flight at once,
    // and their transfer windows overlap in simulated time.
    assert!(
        f.max_concurrent_plans() >= 2,
        "peak concurrent plans {} — the jobs serialized",
        f.max_concurrent_plans()
    );
    assert!(
        oa.started_ns < ob.finished_ns && ob.started_ns < oa.finished_ns,
        "transfer windows did not overlap: A [{}, {}], B [{}, {}]",
        oa.started_ns,
        oa.finished_ns,
        ob.started_ns,
        ob.finished_ns
    );

    // Both tenants' results are bit-exact vs the host oracle (integer
    // seeding makes any reduction order exact), and neither corrupted
    // the other's region.
    let oracle_a = naive_sum(&ga);
    let oracle_b = naive_sum(&gb);
    for r in 0..4 {
        assert_eq!(job_a.read_vector(&mut f, r, elements).unwrap(), oracle_a);
        assert_eq!(job_b.read_vector(&mut f, r, elements).unwrap(), oracle_b);
    }
}

/// Stage one deterministic per-tensor dataset into a layout's spans.
fn stage_tensors(
    f: &mut Fabric,
    comm: &netdam::comm::Communicator,
    buckets: &[GradBucket],
    ranks: usize,
) {
    for b in buckets {
        for t in &b.tensors {
            for r in 0..ranks {
                // Integer-valued, tensor- and rank-keyed: exact sums.
                let data: Vec<f32> = (0..t.elems)
                    .map(|i| ((t.tensor * 13 + r * 7 + i) % 33) as f32 - 16.0)
                    .collect();
                comm.write_vector(f, r, t.offset_elems, &data).unwrap();
            }
        }
    }
}

/// The fusion layer is semantically invisible: a fused bucket stream
/// produces bit-identical per-tensor results to one collective per
/// tensor.
#[test]
fn fused_buckets_bit_identical_to_unfused() {
    let ranks = 4usize;
    let sizes: Vec<usize> = (0..18).map(|i| 96 + (i * 61) % 900).collect();
    let fused = plan_buckets(&sizes, ranks * 2048, ranks);
    let unfused = plan_buckets(&sizes, 0, ranks);
    assert!(fused.len() < unfused.len(), "fusion must actually fuse");

    let run = |buckets: &[GradBucket]| -> Vec<Vec<f32>> {
        let mut f = Fabric::builder().star(ranks).seed(0xF5).build().unwrap();
        let footprint = buckets_total_elems(buckets);
        let comm = f.communicator(footprint as u64 * 4).unwrap();
        stage_tensors(&mut f, &comm, buckets, ranks);
        for h in comm.iallreduce_buckets(&mut f, buckets).unwrap() {
            let o = f.wait(h).unwrap();
            assert!(o.complete());
        }
        // Read every tensor span back from rank 0 (all ranks hold the
        // allreduced value).
        let mut out = vec![Vec::new(); sizes.len()];
        for b in buckets {
            for t in &b.tensors {
                out[t.tensor] = comm
                    .read_vector_at(&mut f, 0, t.offset_elems, t.elems)
                    .unwrap();
            }
        }
        out
    };
    let fused_out = run(&fused);
    let unfused_out = run(&unfused);
    for (k, size) in sizes.iter().enumerate() {
        // Host oracle for tensor k: elementwise sum over ranks.
        let want: Vec<f32> = (0..*size)
            .map(|i| {
                (0..ranks)
                    .map(|r| ((k * 13 + r * 7 + i) % 33) as f32 - 16.0)
                    .sum()
            })
            .collect();
        assert_eq!(fused_out[k], want, "tensor {k} (fused) vs oracle");
        assert_eq!(
            fused_out[k], unfused_out[k],
            "tensor {k}: fused and unfused results must be bit-identical"
        );
    }
}

/// A NAK in one tenant's plan cancels only that plan: the neighbor's
/// memory plan and a concurrent collective complete untouched, and the
/// cancellation stops the device from being hammered with the rest of
/// the bad plan's window.
#[test]
fn nak_in_one_job_cancels_only_that_plan() {
    let elements = 4 * 2048;
    let mut f = Fabric::builder()
        .star(4)
        .hosts(2)
        .window(2) // small window → most of the bad plan is still queued
        .with_pool(1 << 20)
        .seed(0x7A)
        .build()
        .unwrap();

    // Tenant C: a collective job sharing the same fabric.
    let comm = f.communicator(elements as u64 * 4).unwrap();
    let grads = comm.seed_gradients_exact(&mut f, elements, 3);

    // Tenant A (good) and tenant B (about to be denied).
    let client_a = f.mem_client().unwrap();
    let client_b = f.mem_client().unwrap();
    let lease_a = f.malloc(client_a.tenant, 256 << 10, true).unwrap();

    let data: Vec<u8> = (0..256 << 10).map(|i| (i * 31 % 251) as u8).collect();
    let mut batch_a = client_a.batch();
    batch_a.write(f.cluster_mut(), lease_a.gva, &data);
    let h_read = {
        let mut b = client_a.batch();
        let h = b.read(f.cluster_mut(), lease_a.gva, 64 << 10);
        (b, h)
    };

    // Tenant B writes into tenant A's lease: every packet will be
    // denied on the device (foreign lease) — 32 packets, but with the
    // per-plan cancel only the in-flight window's worth should ever
    // reach the devices.
    let bad_bytes = vec![0xEEu8; 256 << 10];
    let mut batch_b = client_b.batch();
    batch_b.write(f.cluster_mut(), lease_a.gva, &bad_bytes);
    let bad_pkts = batch_b.len();
    assert!(bad_pkts >= 32, "want a long bad plan, got {bad_pkts}");

    // Everything in flight together on the shared session.
    let hc = comm.iallreduce(&mut f, elements).unwrap();
    let ha = f.submit_mem(batch_a).unwrap();
    let hb = f.submit_mem(batch_b).unwrap();
    let (br, hr) = h_read;
    let err = f.wait_mem(hb).unwrap_err();
    assert!(
        matches!(
            err,
            MemError::Nak {
                reason: netdam::iommu::NakReason::ForeignLease,
                ..
            }
        ),
        "{err:?}"
    );

    // Neighbors unaffected: A's write landed, the collective finished.
    f.wait_mem(ha).unwrap();
    let oc = f.wait(hc).unwrap();
    assert!(oc.complete(), "collective: {}/{}", oc.ops_done, oc.ops);
    let oracle = naive_sum(&grads);
    for r in 0..4 {
        assert_eq!(comm.read_vector(&mut f, r, elements).unwrap(), oracle);
    }
    let h2 = f.submit_mem(br).unwrap();
    let mut res = f.wait_mem(h2).unwrap();
    assert_eq!(
        res.take_read(hr).unwrap(),
        data[..64 << 10],
        "tenant A's data survived tenant B's denial"
    );

    // The cancel actually stopped the bad plan: far fewer NAKs on the
    // devices than the plan had packets.
    let naks: u64 = (0..4)
        .map(|i| {
            let d = f.devices()[i];
            f.cluster().device(d).iommu_naks
        })
        .sum();
    assert!(naks >= 1, "the denial must have happened on a device");
    assert!(
        (naks as usize) < bad_pkts,
        "{naks} NAKs for a {bad_pkts}-packet plan — cancellation never kicked in"
    );
}

/// The rooted reduce rides the session API end to end.
#[test]
fn ireduce_lands_the_sum_at_root_via_the_session() {
    let elements = 3 * 2048;
    let mut f = Fabric::builder().star(4).seed(0x5EED).build().unwrap();
    let comm = f.communicator(elements as u64 * 4).unwrap();
    let grads = comm.seed_gradients_exact(&mut f, elements, 77);
    let root = 2usize;
    let h = comm.ireduce(&mut f, elements, root).unwrap();
    let out = f.wait(h).unwrap();
    assert!(out.complete());
    assert_eq!(out.algorithm, "reduce");
    let oracle = naive_sum(&grads);
    for r in 0..4 {
        let got = comm.read_vector(&mut f, r, elements).unwrap();
        if r == root {
            assert_eq!(got, oracle, "root holds the full sum");
        } else {
            assert_eq!(got, grads[r], "rank {r} keeps pristine data");
        }
    }
}

/// A token-bucket-paced memory client rides the SHARED session (it used
/// to be rejected with `MemError::Plan`): its plan throttles to the
/// configured rate as a plan-private pacer, while an unpaced neighbor
/// on the same session flows at full rate.
#[test]
fn paced_mem_batch_rides_the_shared_session() {
    let bytes = 64 << 10;
    let mut f = Fabric::builder()
        .star(4)
        .hosts(2)
        .seed(0x9ACE)
        .with_pool(1 << 20)
        .build()
        .unwrap();
    let client = f.mem_client().unwrap();
    let lease = f.malloc(client.tenant, bytes as u64, true).unwrap();
    let data: Vec<u8> = (0..bytes).map(|i| (i * 11 % 253) as u8).collect();
    f.mem_write(&client, lease.gva, &data).unwrap();
    let t0 = f.now();
    assert_eq!(f.mem_read(&client, lease.gva, bytes).unwrap(), data);
    let unpaced_ns = f.now() - t0;

    // 8 Gbps = 1 B/ns with an 8 KiB burst: 64 KiB must take at least
    // (64 - 8) KiB of refill time — same bound as the standalone paced
    // runner, now enforced on the shared session.
    let paced = client.clone_with_pace(8.0, 8 << 10);
    let t0 = f.now();
    assert_eq!(f.mem_read(&paced, lease.gva, bytes).unwrap(), data);
    let paced_ns = f.now() - t0;
    assert!(
        paced_ns >= (56 << 10) as u64,
        "paced session read finished in {paced_ns} ns — faster than the bucket allows"
    );
    assert!(paced_ns > unpaced_ns, "pacing must actually throttle");

    // The pacer is plan-private: an unpaced neighbor submitted alongside
    // a paced plan completes at full speed (well before the paced plan).
    let neighbor = f.mem_client().unwrap();
    let n_lease = f.malloc(neighbor.tenant, bytes as u64, true).unwrap();
    let mut nb = neighbor.batch();
    nb.write(f.cluster_mut(), n_lease.gva, &data);
    let mut pb = paced.batch();
    let pr = pb.read(f.cluster_mut(), lease.gva, bytes);
    let hp = f.submit_mem(pb).unwrap();
    let hn = f.submit_mem(nb).unwrap();
    assert!(f.max_concurrent_plans() >= 2);
    f.wait_mem(hn).unwrap();
    let mut res = f.wait_mem(hp).unwrap();
    assert_eq!(res.take_read(pr).unwrap(), data);
    assert_eq!(f.mem_read(&neighbor, n_lease.gva, bytes).unwrap(), data);
}

/// Reliability still holds on the shared session: two tenants, lossy
/// fabric, reliable communicators — both converge exactly.
#[test]
fn concurrent_reliable_allreduces_survive_loss() {
    let elements = 2 * 2048;
    let mut f = Fabric::builder()
        .star(4)
        .seed(0x10)
        .reliable(true)
        .loss(0.02)
        .window(2)
        .build()
        .unwrap();
    let job_a = f.communicator(elements as u64 * 4).unwrap();
    let job_b = f.communicator(elements as u64 * 4).unwrap();
    let ga = job_a.seed_gradients_exact(&mut f, elements, 1);
    let gb = job_b.seed_gradients_exact(&mut f, elements, 2);
    let ha = job_a.iallreduce(&mut f, elements).unwrap();
    let hb = job_b.iallreduce(&mut f, elements).unwrap();
    let oa = f.wait(ha).unwrap();
    let ob = f.wait(hb).unwrap();
    assert!(oa.complete() && ob.complete(), "loss recovered for both");
    let oracle_a = naive_sum(&ga);
    let oracle_b = naive_sum(&gb);
    for r in 0..4 {
        assert_eq!(job_a.read_vector(&mut f, r, elements).unwrap(), oracle_a);
        assert_eq!(job_b.read_vector(&mut f, r, elements).unwrap(), oracle_b);
    }
}

/// Closed-loop DCQCN on a shared fabric must not starve a tenant: two
/// symmetric jobs under tight RED marking both complete, both stay
/// bit-exact, both genuinely overlap, and their transfer times stay
/// within a small factor of each other — the per-slot controllers cut
/// and recover independently instead of collapsing one tenant to the
/// rate floor while the other free-rides.
#[test]
fn dcqcn_shares_the_fabric_without_starving_a_tenant() {
    use netdam::net::LinkConfig;
    use netdam::roce::DcqcnConfig;
    use netdam::transport::CcMode;

    let elements = 4 * 2048;
    let mut f = Fabric::builder()
        .star(4)
        .link(LinkConfig::dc_100g().with_ecn(4_000, 40_000))
        .seed(0x2B)
        .window(8)
        .with_congestion_control(CcMode::Dcqcn(DcqcnConfig::default()))
        .build()
        .unwrap();
    let job_a = f.communicator(elements as u64 * 4).unwrap();
    let job_b = f.communicator(elements as u64 * 4).unwrap();
    let ga = job_a.seed_gradients_exact(&mut f, elements, 0xA11);
    let gb = job_b.seed_gradients_exact(&mut f, elements, 0xB22);
    let ha = job_a.iallreduce(&mut f, elements).unwrap();
    let hb = job_b.iallreduce(&mut f, elements).unwrap();
    let oa = f.wait(ha).unwrap();
    let ob = f.wait(hb).unwrap();
    assert!(oa.complete(), "job A: {}/{}", oa.ops_done, oa.ops);
    assert!(ob.complete(), "job B: {}/{}", ob.ops_done, ob.ops);
    assert!(f.max_concurrent_plans() >= 2, "the jobs serialized");
    // The loop actually engaged on this fabric (marks → CNPs), and the
    // rate trajectory recorded the controllers' moves.
    assert!(f.cnps() > 0, "no CNPs — DCQCN never engaged");
    assert!(!f.rate_log().is_empty());
    // Fairness between symmetric tenants: neither runs an order of
    // magnitude longer than the other.
    let (ta, tb) = (oa.elapsed_ns().max(1), ob.elapsed_ns().max(1));
    let ratio = ta.max(tb) as f64 / ta.min(tb) as f64;
    assert!(
        ratio < 4.0,
        "tenant starvation under DCQCN: elapsed {ta} vs {tb} ns ({ratio:.2}x)"
    );
    // Adaptive pacing must not corrupt results.
    let oracle_a = naive_sum(&ga);
    let oracle_b = naive_sum(&gb);
    for r in 0..4 {
        assert_eq!(job_a.read_vector(&mut f, r, elements).unwrap(), oracle_a);
        assert_eq!(job_b.read_vector(&mut f, r, elements).unwrap(), oracle_b);
    }
}
