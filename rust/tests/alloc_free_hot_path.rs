//! The PR 9 acceptance gate, enforced: **zero heap allocations on the
//! steady-state packet path**. A counting global allocator (filtered to
//! the measuring thread, serialized across tests) watches three layers:
//!
//! * scalar payload constructors (`Payload::empty` / `Payload::from_u64`
//!   store inline — no `Vec` behind a one-word payload);
//! * warmed timer-wheel churn (arm / cancel / fire recycle slab slots
//!   through the freelist — no per-timer allocation);
//! * the full cluster round trip: reliable `Write` → device → `WriteAck`
//!   → completion (typed events by value, shallow packet clones into the
//!   retransmit buffer, wheel-armed timers exactly cancelled).
//!
//! Methodology: every container on the path grows during a warmup phase
//! that is deliberately larger than the measured phase, so the measured
//! phase runs entirely inside already-reserved capacity — any allocation
//! it performs is a real per-event regression, not amortized growth.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use netdam::isa::{Flags, Instruction};
use netdam::net::{Cluster, NodeId, Topology};
use netdam::sim::{Engine, TimerWheel};
use netdam::wire::{DeviceIp, Packet, Payload, SrouHeader};

static COUNTED: AtomicU64 = AtomicU64::new(0);
// Only allocations made by the thread that set this flag are counted, so
// the harness / other test threads can't pollute the measurement.
thread_local! {
    static MEASURING: Cell<bool> = const { Cell::new(false) };
}
// Serializes measured sections: at most one test is counting at a time.
static SERIAL: Mutex<()> = Mutex::new(());

struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        if MEASURING.try_with(|m| m.get()).unwrap_or(false) {
            COUNTED.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        if MEASURING.try_with(|m| m.get()).unwrap_or(false) {
            COUNTED.fetch_add(1, Ordering::Relaxed);
        }
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Run `f` with allocation counting enabled on this thread, returning
/// `(allocations, result)`.
fn count_allocs<R>(f: impl FnOnce() -> R) -> (u64, R) {
    let _serial = SERIAL.lock().unwrap();
    MEASURING.with(|m| m.set(true));
    let before = COUNTED.load(Ordering::Relaxed);
    let out = f();
    let after = COUNTED.load(Ordering::Relaxed);
    MEASURING.with(|m| m.set(false));
    (after - before, out)
}

#[test]
fn scalar_payload_constructors_do_not_allocate() {
    let (allocs, total_len) = count_allocs(|| {
        let mut acc = 0usize;
        for i in 0..1_000u64 {
            let p = std::hint::black_box(Payload::from_u64(i));
            acc += p.len();
            let e = std::hint::black_box(Payload::empty());
            acc += e.len();
        }
        acc
    });
    assert_eq!(total_len, 8_000, "from_u64 carries its 8 bytes inline");
    assert_eq!(
        allocs, 0,
        "Payload::empty / Payload::from_u64 must store inline ({allocs} allocations)"
    );
}

#[test]
fn warmed_timer_wheel_churn_does_not_allocate() {
    let mut w: TimerWheel<u64> = TimerWheel::new();
    let mut ids = Vec::with_capacity(256);
    let mut now = 0u64;
    let mut seq = 0u64;
    let mut churn = |w: &mut TimerWheel<u64>,
                     ids: &mut Vec<netdam::sim::TimerId>,
                     now: &mut u64,
                     seq: &mut u64,
                     rounds: usize| {
        for _ in 0..rounds {
            for i in 0..64u64 {
                ids.push(w.arm(*now + 30_000 + i * 1_500, *seq, *seq));
                *seq += 1;
            }
            // Cancel the even half exactly (the completion pattern) ...
            for (k, id) in ids.drain(..).enumerate() {
                if k % 2 == 0 {
                    assert!(w.cancel(id), "live timer must cancel");
                }
            }
            // ... and fire the rest in key order (the timeout pattern).
            while let Some((t, _s, _v)) = w.pop_min() {
                assert!(t >= *now, "fired early");
                *now = t;
                w.advance_to(t);
            }
        }
    };
    // Warmup: grows the slab and freelist to peak concurrency.
    churn(&mut w, &mut ids, &mut now, &mut seq, 4);
    let (allocs, ()) = count_allocs(|| churn(&mut w, &mut ids, &mut now, &mut seq, 100));
    assert!(w.is_empty());
    assert_eq!(
        allocs, 0,
        "warmed arm/cancel/fire churn must recycle slab slots ({allocs} allocations)"
    );
}

/// Inject `n` reliable single-packet writes (device `origin` → `dst`),
/// draining the engine after each batch of 8 so several ops — and their
/// wheel timers — are in flight together.
fn drive_writes(
    cl: &mut Cluster,
    eng: &mut Engine<Cluster>,
    origin: NodeId,
    src: DeviceIp,
    dst: DeviceIp,
    n: usize,
) {
    for batch in 0..n / 8 {
        for i in 0..8 {
            let seq = cl.alloc_seq(origin);
            let pkt = Packet::new(
                src,
                seq,
                SrouHeader::direct(dst),
                Instruction::Write { addr: 0 },
            )
            .with_flags(Flags(Flags::RELIABLE))
            .with_payload(Payload::from_u64((batch * 8 + i) as u64));
            cl.inject_reliable(eng, origin, pkt);
        }
        eng.run(cl);
    }
}

#[test]
fn steady_state_write_ack_round_trips_allocate_nothing() {
    let t = Topology::star(0xA110C, 2, 0, netdam::net::LinkConfig::dc_100g());
    let mut cl = t.cluster;
    let mut eng: Engine<Cluster> = Engine::new();
    let (origin, src, dst) = (t.devices[0], DeviceIp::lan(1), DeviceIp::lan(2));

    // Warmup: 608 round trips. Every per-op container (engine heap,
    // wheel slab, reliability table, emit scratch, switch queues, device
    // and cluster completion logs) reaches a capacity comfortably above
    // what warmup + measurement together will ever hold.
    drive_writes(&mut cl, &mut eng, origin, src, dst, 608);
    let completions_before = cl.completions.len();

    let (allocs, ()) = count_allocs(|| drive_writes(&mut cl, &mut eng, origin, src, dst, 240));

    assert_eq!(
        cl.completions.len() - completions_before,
        240,
        "every measured op completed"
    );
    assert_eq!(cl.xport.outstanding(), 0, "no dangling reliability entries");
    assert_eq!(
        cl.metrics.counter("retransmits"),
        0,
        "loss-free run must not retransmit"
    );
    assert_eq!(
        allocs, 0,
        "steady-state Write→WriteAck round trips must not touch the heap \
         ({allocs} allocations across 240 ops)"
    );
}
