//! L1 ↔ L3 integration: the compiled Pallas artifacts, executed through
//! PJRT from rust, must agree bit-for-bit with the native rust ALU (which
//! the python tests in turn pin against the jnp oracle). Requires
//! `make artifacts`; tests skip with a notice when artifacts are absent.

use netdam::alu::{block_hash, AluBackend, NativeAlu};
use netdam::isa::registry::MemAccess;
use netdam::isa::SimdOp;
use netdam::runtime::{backends_agree, Runtime, XlaAlu, ALU_CHUNK};
use netdam::util::bytes::f32s_to_bytes;
use netdam::util::Xoshiro256;

fn artifacts_present() -> bool {
    let ok = std::path::Path::new("artifacts/abi.txt").exists();
    if !ok {
        eprintln!("skipping: artifacts/ missing — run `make artifacts`");
    }
    ok
}

#[test]
fn all_ops_agree_native_vs_pallas() {
    if !artifacts_present() {
        return;
    }
    let mut xla = XlaAlu::open_default().unwrap();
    let mut rng = Xoshiro256::seed_from(0xA11);
    for op in SimdOp::ALL {
        // One exact chunk and one ragged length.
        for n in [ALU_CHUNK, ALU_CHUNK + 1234] {
            let a = rng.f32_vec(n, -1e6, 1e6);
            let b = rng.f32_vec(n, -1e6, 1e6);
            assert!(
                backends_agree(op, &a, &b, &mut xla),
                "{op:?} diverged at n={n}"
            );
        }
    }
}

#[test]
fn special_values_agree() {
    if !artifacts_present() {
        return;
    }
    let mut xla = XlaAlu::open_default().unwrap();
    let specials = [f32::NAN, f32::INFINITY, f32::NEG_INFINITY, 0.0, -0.0, 3.4e38];
    let mut a = vec![1.0f32; ALU_CHUNK];
    let mut b = vec![2.0f32; ALU_CHUNK];
    for (i, &s) in specials.iter().enumerate() {
        a[i] = s;
        b[specials.len() + i] = s;
    }
    for op in SimdOp::ALL {
        assert!(backends_agree(op, &a, &b, &mut xla), "{op:?} on specials");
    }
}

#[test]
fn block_hash_artifact_matches_rust() {
    if !artifacts_present() {
        return;
    }
    let mut xla = XlaAlu::open_default().unwrap();
    let mut rng = Xoshiro256::seed_from(0x4A5);
    let x = rng.f32_vec(ALU_CHUNK, -50.0, 50.0);
    let hashes = xla.hash_blocks(&x).unwrap();
    assert_eq!(hashes.len(), 8);
    for (i, h) in hashes.iter().enumerate() {
        let block = &x[i * 2048..(i + 1) * 2048];
        assert_eq!(
            *h as u64,
            block_hash(&f32s_to_bytes(block)),
            "block {i} hash"
        );
    }
}

#[test]
fn guarded_reduce_artifact_semantics() {
    if !artifacts_present() {
        return;
    }
    let mut rt = Runtime::open_default().unwrap();
    let mut rng = Xoshiro256::seed_from(0x6A);
    let payload = rng.f32_vec(ALU_CHUNK, -10.0, 10.0);
    let local = rng.f32_vec(ALU_CHUNK, -10.0, 10.0);
    // Correct guards for blocks 0..4, corrupted for 4..8.
    let mut guards: Vec<u32> = (0..8)
        .map(|i| block_hash(&f32s_to_bytes(&local[i * 2048..(i + 1) * 2048])) as u32)
        .collect();
    for g in guards[4..].iter_mut() {
        *g ^= 0xBAD;
    }
    let args = vec![
        xla::Literal::vec1(&payload),
        xla::Literal::vec1(&local),
        xla::Literal::vec1(&guards),
    ];
    let outs = rt.exec("guarded_reduce", &args).unwrap();
    let out: Vec<f32> = outs[0].to_vec().unwrap();
    let wrote: Vec<u32> = outs[1].to_vec().unwrap();
    assert_eq!(wrote, vec![1, 1, 1, 1, 0, 0, 0, 0]);
    let mut native = NativeAlu::new();
    for i in 0..8 {
        let o = &out[i * 2048..(i + 1) * 2048];
        if i < 4 {
            let mut expect = payload[i * 2048..(i + 1) * 2048].to_vec();
            native.apply(SimdOp::Add, &mut expect, &local[i * 2048..(i + 1) * 2048]);
            assert_eq!(o, &expect[..], "guarded block {i} reduced");
        } else {
            assert_eq!(
                o,
                &local[i * 2048..(i + 1) * 2048],
                "corrupted guard passes local through"
            );
        }
    }
}

#[test]
fn device_with_pallas_alu_executes_simd() {
    if !artifacts_present() {
        return;
    }
    // Swap the compiled-Pallas backend into a simulated device and run a
    // SIMD instruction through the fabric: L1 kernels on the L3 datapath.
    use netdam::device::DeviceConfig;
    use netdam::isa::Instruction;
    use netdam::net::{Cluster, LinkConfig, Switch};
    use netdam::sim::Engine;
    use netdam::wire::{DeviceIp, Packet, Payload, SrouHeader};

    let mut cl = Cluster::new(5);
    let sw = cl.add_switch(Switch::tor(None));
    let h = cl.add_host(DeviceIp::lan(101), None);
    let d = cl.add_device(DeviceConfig::paper_default(DeviceIp::lan(1)));
    cl.connect(sw, h, LinkConfig::dc_100g());
    cl.connect(sw, d, LinkConfig::dc_100g());
    cl.compute_routes();
    cl.device_mut(d)
        .set_alu(Box::new(XlaAlu::open_default().unwrap()));

    let mut rng = Xoshiro256::seed_from(77);
    let local = rng.f32_vec(2048, -5.0, 5.0);
    cl.device_mut(d)
        .mem()
        .write(0, &f32s_to_bytes(&local))
        .unwrap();
    let payload = rng.f32_vec(2048, -5.0, 5.0);
    let mut eng: Engine<Cluster> = Engine::new();
    let seq = cl.alloc_seq(h);
    let pkt = Packet::new(
        DeviceIp::lan(101),
        seq,
        SrouHeader::direct(DeviceIp::lan(1)),
        Instruction::Simd {
            op: SimdOp::Mul,
            addr: 0,
        },
    )
    .with_payload(Payload::from_f32s(&payload));
    cl.inject(&mut eng, h, pkt);
    eng.run(&mut cl);
    let (_, resp) = cl.host_mut(h).mailbox.pop().expect("simd response");
    let got = resp.payload.f32s().unwrap().unwrap();
    let mut expect = payload.clone();
    NativeAlu::new().apply(SimdOp::Mul, &mut expect, &local);
    assert_eq!(got, expect, "Pallas-backed device computes correctly");
}

#[test]
fn mlp_training_matches_python_oracle() {
    if !artifacts_present() {
        return;
    }
    let curve = netdam::examples_support::train_dataparallel(5, 4, false).unwrap();
    let reference = netdam::runtime::mlp::MlpTrainer::reference_curve("artifacts").unwrap();
    for i in 0..5 {
        let rel = ((curve[i] - reference[i]) / reference[i]).abs();
        assert!(
            rel < 1e-3,
            "step {i}: rust {} vs oracle {} (rel {rel})",
            curve[i],
            reference[i]
        );
    }
}
