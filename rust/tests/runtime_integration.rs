//! L1 ↔ L3 integration seam, offline edition: the `XlaAlu` backend (the
//! compiled-Pallas calling convention, computing natively in this build)
//! must agree bit-for-bit with the native rust ALU, and the runtime must
//! fail loudly — not silently — when PJRT artifacts are unavailable.

use netdam::alu::{block_hash, AluBackend, NativeAlu};
use netdam::isa::SimdOp;
use netdam::runtime::{backends_agree, Runtime, XlaAlu, ALU_CHUNK};
use netdam::util::bytes::f32s_to_bytes;
use netdam::util::Xoshiro256;

#[test]
fn all_ops_agree_native_vs_stub_backend() {
    let mut xla = XlaAlu::open_default().unwrap();
    let mut rng = Xoshiro256::seed_from(0xA11);
    for op in SimdOp::ALL {
        // One exact chunk and one ragged length.
        for n in [ALU_CHUNK, ALU_CHUNK + 1234] {
            let a = rng.f32_vec(n, -1e6, 1e6);
            let b = rng.f32_vec(n, -1e6, 1e6);
            assert!(
                backends_agree(op, &a, &b, &mut xla),
                "{op:?} diverged at n={n}"
            );
        }
    }
    assert!(xla.calls > 0, "chunked calls must be accounted");
}

#[test]
fn special_values_agree() {
    let mut xla = XlaAlu::open_default().unwrap();
    let specials = [f32::NAN, f32::INFINITY, f32::NEG_INFINITY, 0.0, -0.0, 3.4e38];
    let mut a = vec![1.0f32; ALU_CHUNK];
    let mut b = vec![2.0f32; ALU_CHUNK];
    for (i, &s) in specials.iter().enumerate() {
        a[i] = s;
        b[specials.len() + i] = s;
    }
    for op in SimdOp::ALL {
        assert!(backends_agree(op, &a, &b, &mut xla), "{op:?} on specials");
    }
}

#[test]
fn block_hash_abi_matches_rust() {
    let mut xla = XlaAlu::open_default().unwrap();
    let mut rng = Xoshiro256::seed_from(0x4A5);
    let x = rng.f32_vec(ALU_CHUNK, -50.0, 50.0);
    let hashes = xla.hash_blocks(&x).unwrap();
    assert_eq!(hashes.len(), 8);
    for (i, h) in hashes.iter().enumerate() {
        let block = &x[i * 2048..(i + 1) * 2048];
        assert_eq!(
            *h,
            block_hash(&f32s_to_bytes(block)) as u32,
            "block {i} hash"
        );
    }
    // Partial chunks are a caller bug under the artifact ABI.
    assert!(xla.hash_blocks(&x[..2048]).is_err());
}

#[test]
fn runtime_reports_missing_artifacts() {
    // No artifacts/ directory in the offline build: open must fail with
    // actionable context rather than panic or succeed vacuously.
    if std::path::Path::new("artifacts/abi.txt").exists() {
        return; // someone ran `make artifacts`; nothing to assert here
    }
    let err = Runtime::open_default().unwrap_err().to_string();
    assert!(err.contains("abi.txt"), "unexpected error: {err}");
}

#[test]
fn device_with_stub_alu_executes_simd() {
    // Swap the artifact-convention backend into a simulated device and run
    // a SIMD instruction through the fabric — the L1→L3 seam stays wired
    // even without PJRT.
    use netdam::device::DeviceConfig;
    use netdam::isa::registry::MemAccess;
    use netdam::isa::Instruction;
    use netdam::net::{Cluster, LinkConfig, Switch};
    use netdam::sim::Engine;
    use netdam::wire::{DeviceIp, Packet, Payload, SrouHeader};

    let mut cl = Cluster::new(5);
    let sw = cl.add_switch(Switch::tor(None));
    let h = cl.add_host(DeviceIp::lan(101), None);
    let d = cl.add_device(DeviceConfig::paper_default(DeviceIp::lan(1)));
    cl.connect(sw, h, LinkConfig::dc_100g());
    cl.connect(sw, d, LinkConfig::dc_100g());
    cl.compute_routes();
    cl.device_mut(d)
        .set_alu(Box::new(XlaAlu::open_default().unwrap()));

    let mut rng = Xoshiro256::seed_from(77);
    let local = rng.f32_vec(2048, -5.0, 5.0);
    cl.device_mut(d)
        .mem()
        .write(0, &f32s_to_bytes(&local))
        .unwrap();
    let payload = rng.f32_vec(2048, -5.0, 5.0);
    let mut eng: Engine<Cluster> = Engine::new();
    let seq = cl.alloc_seq(h);
    let pkt = Packet::new(
        DeviceIp::lan(101),
        seq,
        SrouHeader::direct(DeviceIp::lan(1)),
        Instruction::Simd {
            op: SimdOp::Mul,
            addr: 0,
        },
    )
    .with_payload(Payload::from_f32s(&payload));
    cl.inject(&mut eng, h, pkt);
    eng.run(&mut cl);
    let (_, resp) = cl.host_mut(h).mailbox.pop().expect("simd response");
    let got = resp.payload.f32s().unwrap().unwrap();
    let mut expect = payload.clone();
    NativeAlu::new().apply(SimdOp::Mul, &mut expect, &local);
    assert_eq!(got, expect, "artifact-convention backend computes correctly");
}
