//! PR 9 regression: the allocation-free hot path — typed packet events,
//! Arc-shared packet bodies, and timer-wheel retransmit timers — must
//! not move a single observable bit. These tests pin the full shard
//! grid {0, 1, 2, 4} under 5% loss: reports, metrics counters, final
//! data, and DCQCN rate trajectories.
//!
//! Grid convention (same as `sharded_determinism.rs`): sharded arms are
//! bit-compared against *each other*; the classic engine (shards = 0)
//! draws from a different RNG stream layout under loss by design, so
//! its arm is pinned by **self-reproduction** (two identical runs) plus
//! the data oracle shared with every sharded arm.

use netdam::collectives::{naive_sum, CollectiveReport};
use netdam::comm::Fabric;
use netdam::net::LinkConfig;
use netdam::roce::DcqcnConfig;
use netdam::transport::CcMode;

/// A lossy, reliable ring allreduce on the 2-pod fat-tree. `shards == 0`
/// runs the classic single-heap engine (wheel-armed retransmit timers,
/// exact cancellation); `shards > 0` runs the sharded core (epoch-guarded
/// heap retries). Returns the report, a counter snapshot, and every
/// rank's final vector.
fn lossy_run(shards: usize) -> (CollectiveReport, Vec<(String, u64)>, Vec<Vec<f32>>) {
    let elements = 8 * 512;
    let mut builder = Fabric::builder()
        .fat_tree(2, 4, 2)
        .seed(0xD15C)
        .reliable(true)
        .loss(0.05)
        .window(4)
        .with_shards(shards);
    if shards > 0 {
        builder = builder.shard_threads(1);
    }
    let mut f = builder.build().unwrap();
    let comm = f.communicator(elements as u64 * 4).unwrap();
    let grads = comm.seed_gradients_exact(&mut f, elements, 0x5EED);
    let h = comm.iallreduce(&mut f, elements).unwrap();
    let out = f.wait(h).unwrap();
    assert!(
        out.complete(),
        "shards={shards}: {}/{} ops",
        out.ops_done,
        out.ops
    );
    let report = f.report(&out);
    let counters: Vec<(String, u64)> = ["link_drops", "fault_lost", "retransmits", "fault_duplicated"]
        .iter()
        .map(|&k| (k.to_string(), f.cluster().metrics.counter(k)))
        .collect();
    let oracle = naive_sum(&grads);
    let mut vecs = Vec::with_capacity(f.ranks());
    for r in 0..f.ranks() {
        let v = comm.read_vector(&mut f, r, elements).unwrap();
        assert_eq!(v, oracle, "shards={shards}: rank {r} diverged from oracle");
        vecs.push(v);
    }
    (report, counters, vecs)
}

/// Classic engine, 5% loss, run twice: the wheel-based retransmit path
/// (arm on inject, exact cancel on completion, fire + re-arm on loss)
/// reproduces the report, every counter, and every byte of data.
#[test]
fn classic_engine_lossy_run_is_bit_reproducible() {
    let (ra, ca, va) = lossy_run(0);
    let (rb, cb, vb) = lossy_run(0);
    assert!(ra.link_drops > 0, "the loss model never fired: {ra:?}");
    assert!(ra.retransmits > 0, "loss recovered without retransmits?");
    assert_eq!(ra, rb, "classic report, run A vs run B");
    assert_eq!(ca, cb, "classic counters, run A vs run B");
    assert_eq!(va, vb, "classic data, run A vs run B");
}

/// The full grid under loss: sharded arms {1, 2, 4} bit-agree on report,
/// counters, and data; the classic arm recovers the same oracle through
/// its own retransmit machinery.
#[test]
fn lossy_grid_reports_counters_and_data_pin_the_hot_path() {
    let (r0, c0, v0) = lossy_run(0);
    let (r1, c1, v1) = lossy_run(1);
    let (r2, c2, v2) = lossy_run(2);
    let (r4, c4, v4) = lossy_run(4);
    assert!(r1.link_drops > 0 && r1.retransmits > 0, "{r1:?}");
    assert_eq!(r1, r2, "report, 1 vs 2 shards");
    assert_eq!(r1, r4, "report, 1 vs 4 shards");
    assert_eq!(c1, c2, "counters, 1 vs 2 shards");
    assert_eq!(c1, c4, "counters, 1 vs 4 shards");
    assert_eq!(v1, v2, "data, 1 vs 2 shards");
    assert_eq!(v1, v4, "data, 1 vs 4 shards");
    // Classic and sharded agree on the *semantics* even though their
    // loss draws differ: same element count, same final data.
    assert!(r0.retransmits > 0);
    assert_eq!(r0.elements, r1.elements);
    assert_eq!(v0, v1, "classic data matches the sharded grid");
    assert!(c0.iter().any(|(k, v)| k == "retransmits" && *v > 0));
}

/// Same fabric with closed-loop DCQCN active: RED marks, CE echo, CNPs,
/// multiplicative cuts. Returns the report, the CE counter, and the full
/// per-slot rate trajectory.
fn dcqcn_run(shards: usize) -> (CollectiveReport, u64, Vec<(usize, u64, u64)>) {
    let elements = 8 * 512;
    let mut builder = Fabric::builder()
        .fat_tree(2, 4, 2)
        .link(LinkConfig::dc_100g().with_ecn(2_000, 20_000))
        .seed(0xD15C)
        .reliable(true)
        .loss(0.05)
        .window(4)
        .with_congestion_control(CcMode::Dcqcn(DcqcnConfig::default()))
        .with_shards(shards);
    if shards > 0 {
        builder = builder.shard_threads(1);
    }
    let mut f = builder.build().unwrap();
    let comm = f.communicator(elements as u64 * 4).unwrap();
    let grads = comm.seed_gradients_exact(&mut f, elements, 0x5EED);
    let h = comm.iallreduce(&mut f, elements).unwrap();
    let out = f.wait(h).unwrap();
    assert!(out.complete(), "shards={shards}");
    let oracle = naive_sum(&grads);
    let v = comm.read_vector(&mut f, 0, elements).unwrap();
    assert_eq!(v, oracle, "shards={shards}: data diverged");
    let ce = f.cluster().metrics.counter("ecn_ce_received");
    (f.report(&out), ce, f.rate_log())
}

/// Rate trajectories across the grid: the control loop replays
/// bit-identically at shards {1, 2, 4}, and the classic engine replays
/// itself exactly — every CNP absorbed at the same instant with the
/// same f64 rate bits, now with its retransmit timers on the wheel.
#[test]
fn dcqcn_rate_trajectories_pin_the_hot_path() {
    let (r0a, ce0a, t0a) = dcqcn_run(0);
    let (r0b, ce0b, t0b) = dcqcn_run(0);
    assert!(ce0a > 0, "classic: no CE marks echoed");
    assert!(!t0a.is_empty(), "classic: DCQCN never absorbed a CNP");
    assert_eq!(r0a, r0b, "classic report, run A vs run B");
    assert_eq!(ce0a, ce0b, "classic CE count, run A vs run B");
    assert_eq!(t0a, t0b, "classic rate trajectory, run A vs run B");

    let (r1, ce1, t1) = dcqcn_run(1);
    let (r2, ce2, t2) = dcqcn_run(2);
    let (r4, ce4, t4) = dcqcn_run(4);
    assert!(ce1 > 0 && !t1.is_empty());
    assert_eq!(r1, r2, "report, 1 vs 2 shards");
    assert_eq!(r1, r4, "report, 1 vs 4 shards");
    assert_eq!(ce1, ce2, "CE count, 1 vs 2 shards");
    assert_eq!(ce1, ce4, "CE count, 1 vs 4 shards");
    assert_eq!(t1, t2, "rate trajectory, 1 vs 2 shards");
    assert_eq!(t1, t4, "rate trajectory, 1 vs 4 shards");
}
