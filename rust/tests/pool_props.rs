//! Property tests for the SDN controller's allocator + ACL (§2.6),
//! driven by the in-tree `util::prop` harness: random malloc/free
//! interleavings never overlap, freed space always coalesces back to a
//! canonical free list, and the ACL agrees with the live lease set.

use netdam::pool::{AllocError, Allocation, InterleaveMap, SdnController};
use netdam::util::prop;
use netdam::util::Xoshiro256;
use netdam::wire::DeviceIp;

const BLOCK: u64 = 8192;

fn ctl() -> SdnController {
    let map = InterleaveMap::paper_default((1..=4).map(DeviceIp::lan).collect());
    SdnController::new(map, 1 << 20) // 4 MiB pool
}

fn overlap(a: &Allocation, b: &Allocation) -> bool {
    a.gva < b.gva + b.len && b.gva < a.gva + a.len
}

/// Random malloc/free interleaving; returns the live allocation set.
fn random_walk(rng: &mut Xoshiro256, c: &mut SdnController, steps: usize) -> Vec<Allocation> {
    let mut live: Vec<Allocation> = Vec::new();
    for _ in 0..steps {
        if rng.chance(0.6) || live.is_empty() {
            let tenant = (1 + rng.next_below(3)) as u32;
            let bytes = 1 + rng.next_below(64 * BLOCK);
            let writable = rng.chance(0.7);
            match c.malloc(tenant, bytes, writable) {
                Ok(a) => {
                    assert_eq!(a.len % BLOCK, 0, "granule-rounded");
                    assert!(a.len >= bytes, "covers the request");
                    live.push(a);
                }
                Err(AllocError::Exhausted { requested, .. }) => {
                    assert_eq!(requested, bytes, "reports the caller's bytes");
                }
                Err(e) => panic!("unexpected malloc error {e:?}"),
            }
        } else {
            let idx = rng.next_below(live.len() as u64) as usize;
            let a = live.swap_remove(idx);
            c.free(a.tenant, a.gva).expect("owned free succeeds");
        }
    }
    live
}

#[test]
fn interleavings_never_overlap_and_stay_in_bounds() {
    prop::check(|rng, _case| {
        let mut c = ctl();
        let live = random_walk(rng, &mut c, 60);
        for (i, a) in live.iter().enumerate() {
            assert!(a.gva + a.len <= c.capacity(), "in bounds");
            for b in &live[..i] {
                assert!(!overlap(a, b), "live allocations overlap: {a:?} / {b:?}");
            }
        }
        let total: u64 = live.iter().map(|a| a.len).sum();
        assert_eq!(total, c.allocated_bytes());
    });
}

#[test]
fn freeing_everything_coalesces_to_one_canonical_hole() {
    prop::check(|rng, _case| {
        let mut c = ctl();
        let mut live = random_walk(rng, &mut c, 40);
        // Free in random order; holes must coalesce back to one span.
        while !live.is_empty() {
            let idx = rng.next_below(live.len() as u64) as usize;
            let a = live.swap_remove(idx);
            c.free(a.tenant, a.gva).unwrap();
        }
        assert_eq!(c.allocated_bytes(), 0);
        // The canonical free list = one hole of the whole capacity: a
        // full-pool malloc succeeds again.
        let whole = c.capacity();
        let big = c.malloc(9, whole, true).expect("free list re-coalesced");
        assert_eq!((big.gva, big.len), (0, whole));
    });
}

#[test]
fn acl_agrees_with_the_live_lease_set() {
    prop::check(|rng, _case| {
        let mut c = ctl();
        let live = random_walk(rng, &mut c, 40);
        // Random probes: the controller's answer must match the model
        // derived from the returned allocations.
        for _ in 0..40 {
            let tenant = (1 + rng.next_below(4)) as u32;
            let gva = rng.next_below(c.capacity());
            let len = 1 + rng.next_below(4 * BLOCK);
            let write = rng.chance(0.5);
            let model_ok = live.iter().any(|a| {
                gva >= a.gva
                    && gva + len <= a.gva + a.len
                    && a.tenant == tenant
                    && (!write || a.writable)
            });
            let got = c.access(tenant, gva, len, write);
            assert_eq!(
                got.is_ok(),
                model_ok,
                "ACL mismatch for tenant {tenant} at [{gva:#x}..+{len}) write={write}"
            );
            if let Ok(extents) = got {
                // Translation covers the probe exactly, in order.
                let covered: u64 = extents.iter().map(|e| e.len).sum();
                assert_eq!(covered, len);
            }
        }
        // Probing a foreign tenant's exact lease is always denied.
        for a in &live {
            let foreign = a.tenant + 100;
            assert!(matches!(
                c.access(foreign, a.gva, a.len, false),
                Err(AllocError::Denied { .. })
            ));
        }
    });
}

#[test]
fn free_rejects_foreign_and_unknown_gvas() {
    prop::check(|rng, _case| {
        let mut c = ctl();
        let live = random_walk(rng, &mut c, 30);
        for a in &live {
            // Wrong tenant cannot free.
            assert_eq!(
                c.free(a.tenant + 100, a.gva),
                Err(AllocError::NotOwned(a.gva))
            );
            // Interior addresses are not allocation handles.
            if a.len > BLOCK {
                assert_eq!(
                    c.free(a.tenant, a.gva + BLOCK),
                    Err(AllocError::NotOwned(a.gva + BLOCK))
                );
            }
        }
    });
}
