//! Property tests for the SDN controller's allocator + ACL (§2.6),
//! driven by the in-tree `util::prop` harness: random malloc/free
//! interleavings never overlap, freed space always coalesces back to a
//! canonical free list, the ACL agrees with the live lease set, and —
//! on a live fabric — a lease revoked mid-flight resolves its in-flight
//! ops to typed NAKs (plan cancelled), never to stale or foreign data.

use netdam::comm::Fabric;
use netdam::mem::MemError;
use netdam::pool::{AllocError, Allocation, InterleaveMap, SdnController};
use netdam::util::prop;
use netdam::util::Xoshiro256;
use netdam::wire::DeviceIp;

const BLOCK: u64 = 8192;

fn ctl() -> SdnController {
    let map = InterleaveMap::paper_default((1..=4).map(DeviceIp::lan).collect());
    SdnController::new(map, 1 << 20) // 4 MiB pool
}

fn overlap(a: &Allocation, b: &Allocation) -> bool {
    a.gva < b.gva + b.len && b.gva < a.gva + a.len
}

/// Random malloc/free interleaving; returns the live allocation set.
fn random_walk(rng: &mut Xoshiro256, c: &mut SdnController, steps: usize) -> Vec<Allocation> {
    let mut live: Vec<Allocation> = Vec::new();
    for _ in 0..steps {
        if rng.chance(0.6) || live.is_empty() {
            let tenant = (1 + rng.next_below(3)) as u32;
            let bytes = 1 + rng.next_below(64 * BLOCK);
            let writable = rng.chance(0.7);
            match c.malloc(tenant, bytes, writable) {
                Ok(a) => {
                    assert_eq!(a.len % BLOCK, 0, "granule-rounded");
                    assert!(a.len >= bytes, "covers the request");
                    live.push(a);
                }
                Err(AllocError::Exhausted { requested, .. }) => {
                    assert_eq!(requested, bytes, "reports the caller's bytes");
                }
                Err(e) => panic!("unexpected malloc error {e:?}"),
            }
        } else {
            let idx = rng.next_below(live.len() as u64) as usize;
            let a = live.swap_remove(idx);
            c.free(a.tenant, a.gva).expect("owned free succeeds");
        }
    }
    live
}

#[test]
fn interleavings_never_overlap_and_stay_in_bounds() {
    prop::check(|rng, _case| {
        let mut c = ctl();
        let live = random_walk(rng, &mut c, 60);
        for (i, a) in live.iter().enumerate() {
            assert!(a.gva + a.len <= c.capacity(), "in bounds");
            for b in &live[..i] {
                assert!(!overlap(a, b), "live allocations overlap: {a:?} / {b:?}");
            }
        }
        let total: u64 = live.iter().map(|a| a.len).sum();
        assert_eq!(total, c.allocated_bytes());
    });
}

#[test]
fn freeing_everything_coalesces_to_one_canonical_hole() {
    prop::check(|rng, _case| {
        let mut c = ctl();
        let mut live = random_walk(rng, &mut c, 40);
        // Free in random order; holes must coalesce back to one span.
        while !live.is_empty() {
            let idx = rng.next_below(live.len() as u64) as usize;
            let a = live.swap_remove(idx);
            c.free(a.tenant, a.gva).unwrap();
        }
        assert_eq!(c.allocated_bytes(), 0);
        // The canonical free list = one hole of the whole capacity: a
        // full-pool malloc succeeds again.
        let whole = c.capacity();
        let big = c.malloc(9, whole, true).expect("free list re-coalesced");
        assert_eq!((big.gva, big.len), (0, whole));
    });
}

#[test]
fn acl_agrees_with_the_live_lease_set() {
    prop::check(|rng, _case| {
        let mut c = ctl();
        let live = random_walk(rng, &mut c, 40);
        // Random probes: the controller's answer must match the model
        // derived from the returned allocations.
        for _ in 0..40 {
            let tenant = (1 + rng.next_below(4)) as u32;
            let gva = rng.next_below(c.capacity());
            let len = 1 + rng.next_below(4 * BLOCK);
            let write = rng.chance(0.5);
            let model_ok = live.iter().any(|a| {
                gva >= a.gva
                    && gva + len <= a.gva + a.len
                    && a.tenant == tenant
                    && (!write || a.writable)
            });
            let got = c.access(tenant, gva, len, write);
            assert_eq!(
                got.is_ok(),
                model_ok,
                "ACL mismatch for tenant {tenant} at [{gva:#x}..+{len}) write={write}"
            );
            if let Ok(extents) = got {
                // Translation covers the probe exactly, in order.
                let covered: u64 = extents.iter().map(|e| e.len).sum();
                assert_eq!(covered, len);
            }
        }
        // Probing a foreign tenant's exact lease is always denied.
        for a in &live {
            let foreign = a.tenant + 100;
            assert!(matches!(
                c.access(foreign, a.gva, a.len, false),
                Err(AllocError::Denied { .. })
            ));
        }
    });
}

#[test]
fn inflight_ops_on_a_freed_lease_die_as_typed_naks_never_stale_reads() {
    prop::check(|rng, _case| {
        let mut fabric = Fabric::builder()
            .star(2)
            .hosts(2)
            .seed(rng.next_u64())
            .with_pool(1 << 20)
            .build()
            .unwrap();
        let victim = fabric.mem_client().unwrap();
        let neighbor = fabric.mem_client().unwrap();

        let blocks = 1 + rng.next_below(4);
        let lease = fabric.malloc(victim.tenant, blocks * BLOCK, true).unwrap();
        let nb = fabric.malloc(neighbor.tenant, BLOCK, true).unwrap();

        // Prime the victim's lease so a stale read would have real
        // bytes to leak, and quiesce.
        let mut b = victim.batch();
        b.write(fabric.cluster_mut(), lease.gva, &[0xAB; 512]);
        let h = fabric.submit_mem(b).unwrap();
        fabric.wait_mem(h).unwrap();

        // Put a fresh victim read plan in flight (submitted, not yet
        // driven), with neighbor traffic alongside.
        let mut b = victim.batch();
        let n_ops = 2 + rng.next_below(6) as usize;
        for _ in 0..n_ops {
            let off = 512 * rng.next_below(blocks * BLOCK / 512);
            b.read(fabric.cluster_mut(), lease.gva + off, 512);
        }
        let victim_h = fabric.submit_mem(b).unwrap();

        let payload: Vec<u8> = (0..768).map(|i| (i as u8).wrapping_mul(13)).collect();
        let mut b = neighbor.batch();
        b.write(fabric.cluster_mut(), nb.gva, &payload);
        let nb_write = fabric.submit_mem(b).unwrap();

        // Revoke the victim's lease while both plans are in flight —
        // and let the neighbor's next malloc reuse the hole at once, so
        // a fenceless device would now serve FOREIGN data to the victim.
        fabric.free(victim.tenant, lease.gva).unwrap();
        let reuse = fabric
            .malloc(neighbor.tenant, blocks * BLOCK, true)
            .unwrap();
        assert_eq!(reuse.gva, lease.gva, "first-fit reuses the freed hole");

        // The victim's plan resolves to a typed NAK inside the revoked
        // lease; nothing completed, the tail was cancelled with it.
        let (res, stats) = fabric.wait_mem_timed(victim_h);
        match res {
            Err(MemError::Nak { gva, .. }) => {
                assert!(
                    gva >= lease.gva && gva < lease.gva + lease.len,
                    "NAK names a gva outside the revoked lease: {gva:#x}"
                );
            }
            other => panic!("expected a typed NAK for the revoked lease, got {other:?}"),
        }
        assert!(stats.nakked);
        assert_eq!(stats.done, 0, "an op completed against a revoked lease");

        // The neighbor never noticed: its in-flight write landed, and
        // both its old lease and the reused granules round-trip.
        fabric.wait_mem(nb_write).unwrap();
        let mut b = neighbor.batch();
        let rb_old = b.read(fabric.cluster_mut(), nb.gva, payload.len());
        b.write(fabric.cluster_mut(), reuse.gva, &payload);
        let h = fabric.submit_mem(b).unwrap();
        let mut out = fabric.wait_mem(h).unwrap();
        assert_eq!(out.take_read(rb_old).unwrap(), payload);

        let mut b = neighbor.batch();
        let rb_new = b.read(fabric.cluster_mut(), reuse.gva, payload.len());
        let h = fabric.submit_mem(b).unwrap();
        let mut out = fabric.wait_mem(h).unwrap();
        assert_eq!(out.take_read(rb_new).unwrap(), payload);
    });
}

#[test]
fn free_rejects_foreign_and_unknown_gvas() {
    prop::check(|rng, _case| {
        let mut c = ctl();
        let live = random_walk(rng, &mut c, 30);
        for a in &live {
            // Wrong tenant cannot free.
            assert_eq!(
                c.free(a.tenant + 100, a.gva),
                Err(AllocError::NotOwned(a.gva))
            );
            // Interior addresses are not allocation handles.
            if a.len > BLOCK {
                assert_eq!(
                    c.free(a.tenant, a.gva + BLOCK),
                    Err(AllocError::NotOwned(a.gva + BLOCK))
                );
            }
        }
    });
}
