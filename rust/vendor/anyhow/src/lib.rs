//! A minimal, dependency-free stand-in for the `anyhow` crate.
//!
//! This build runs fully offline (no crates.io access), so the repo
//! vendors the ~10% of `anyhow` its code actually uses:
//!
//! * [`Error`] — an opaque, message-carrying error type;
//! * [`Result<T>`] — `std::result::Result<T, Error>`;
//! * [`anyhow!`], [`bail!`], [`ensure!`] — format-style constructors;
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`.
//!
//! Like the real crate, [`Error`] deliberately does **not** implement
//! `std::error::Error`; that is what makes the blanket
//! `From<E: std::error::Error>` conversion (the `?` operator's entry
//! point) coherent. Unlike the real crate it flattens the source chain
//! into the rendered message instead of keeping live backtraces — ample
//! for a deterministic simulator's diagnostics.

use std::error::Error as StdError;
use std::fmt;

/// An opaque error: a rendered message (source chain included).
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from anything displayable (the `anyhow!` entry point).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            msg: message.to_string(),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut msg = e.to_string();
        let mut src = e.source();
        while let Some(s) = src {
            msg.push_str(": ");
            msg.push_str(&s.to_string());
            src = s.source();
        }
        Error { msg }
    }
}

/// `anyhow`-style result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach human context to an error as it crosses an abstraction boundary.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| {
            let e: Error = e.into();
            Error::msg(format!("{context}: {e}"))
        })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| {
            let e: Error = e.into();
            Error::msg(format!("{}: {e}", f()))
        })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

/// Build an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!(
                "Condition failed: `",
                stringify!($cond),
                "`"
            )));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<u32> {
        let v: u32 = s.parse()?; // ParseIntError -> Error via blanket From
        ensure!(v < 100, "value {v} too large");
        Ok(v)
    }

    #[test]
    fn question_mark_and_ensure() {
        assert_eq!(parse("42").unwrap(), 42);
        assert!(parse("xx").is_err());
        assert_eq!(parse("500").unwrap_err().to_string(), "value 500 too large");
    }

    #[test]
    fn ensure_without_message() {
        fn check(x: u32) -> Result<()> {
            ensure!(x > 0);
            Ok(())
        }
        let e = check(0).unwrap_err();
        assert!(e.to_string().contains("Condition failed"), "{e}");
    }

    #[test]
    fn context_wraps_message() {
        let r: std::result::Result<(), std::io::Error> = Err(std::io::Error::new(
            std::io::ErrorKind::NotFound,
            "missing",
        ));
        let e = r.context("opening config").unwrap_err();
        let s = e.to_string();
        assert!(s.starts_with("opening config: "), "{s}");
        assert!(s.contains("missing"), "{s}");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        assert_eq!(v.context("empty").unwrap_err().to_string(), "empty");
        let e = Some(5u32).with_context(|| "unused").unwrap();
        assert_eq!(e, 5);
    }

    #[test]
    fn bail_formats() {
        fn f() -> Result<()> {
            bail!("bad state {}", 7)
        }
        assert_eq!(f().unwrap_err().to_string(), "bad state 7");
    }
}
