//! Embedding-bag gather on the pooled memory plane (TensorDIMM-style
//! near-memory reduction).
//!
//! A recommendation model's embedding table lives sharded across the
//! NetDAM pool (block interleaving spreads rows over every device). For
//! each lookup *bag* (a sparse set of row indices), the host does not
//! pull every row over the network: `MemBatch::gather_sum` compiles the
//! bag into ONE self-routing packet program that visits each row's
//! device, folds the row into the packet accumulator with an on-device
//! `Simd` add, and writes the pooled sum into a result slot — only the
//! result row ever crosses the host link, a `bag_size:1` traffic
//! reduction exactly like TensorDIMM's near-memory embedding lookups.
//! All bags are submitted into one pipelined `MemBatch`, so every bag's
//! program is in flight concurrently under the shared window engine
//! (the old API ran one bag per blocking call).
//!
//! ```sh
//! cargo run --release --example embedding_gather
//! ```

use anyhow::Result;
use netdam::mem::MemClient;
use netdam::net::{Cluster, LinkConfig, Topology};
use netdam::pool::{InterleaveMap, SdnController};
use netdam::sim::{fmt_ns, Engine};
use netdam::util::bytes::{bytes_to_f32s, f32s_to_bytes};
use netdam::util::Xoshiro256;
use netdam::wire::DeviceIp;

const ROW_F32: usize = 256; // 1 KiB rows: 8 per interleave block
const ROW_BYTES: usize = ROW_F32 * 4;
const N_ROWS: usize = 512; // 512 KiB table
const N_BAGS: usize = 16;
const BAG: usize = 4;

fn main() -> Result<()> {
    println!("== Embedding-bag gather: near-memory reduce over the pool ==\n");
    let t = Topology::star(0xE1B, 4, 1, LinkConfig::dc_100g());
    let mut cl = t.cluster;
    let mut eng: Engine<Cluster> = Engine::new();
    let map = InterleaveMap::paper_default((1..=4).map(DeviceIp::lan).collect());
    let mut ctl = SdnController::new(map, 2 << 30);

    // Lease the table + result slots; the controller programs the IOMMUs.
    ctl.grant_host(&mut cl, 1, DeviceIp::lan(101));
    let table = ctl.malloc_mapped(&mut cl, 1, (N_ROWS * ROW_BYTES) as u64, true)?;
    let results = ctl.malloc_mapped(&mut cl, 1, (N_BAGS * ROW_BYTES) as u64, true)?;
    let client = MemClient::new(t.hosts[0], DeviceIp::lan(101), 1, ctl.map().clone());

    // Populate the table: row r = [r, r, ...] (easy to verify sums).
    let mut bytes = Vec::with_capacity(N_ROWS * ROW_BYTES);
    for r in 0..N_ROWS {
        bytes.extend_from_slice(&f32s_to_bytes(&vec![r as f32; ROW_F32]));
    }
    client.write(&mut cl, &mut eng, table.gva, &bytes)?;
    println!(
        "table: {} rows x {} f32 sharded over {} devices",
        N_ROWS,
        ROW_F32,
        ctl.map().n_devices()
    );

    // Random bags; each gathers BAG rows near memory. All bags ride ONE
    // pipelined batch: every bag's program is in flight at once under
    // the per-device windows of the shared transport engine.
    let mut rng = Xoshiro256::seed_from(0xBA6);
    let mut expect = Vec::with_capacity(N_BAGS);
    let mut batch = client.batch();
    for b in 0..N_BAGS {
        let rows: Vec<u64> = (0..BAG).map(|_| rng.next_below(N_ROWS as u64)).collect();
        let gvas: Vec<u64> = rows
            .iter()
            .map(|&r| table.gva + r * ROW_BYTES as u64)
            .collect();
        let dst = results.gva + (b * ROW_BYTES) as u64;
        batch.gather_sum(&mut cl, &gvas, ROW_BYTES, dst)?;
        expect.push(rows.iter().sum::<u64>() as f32);
    }
    let t0 = eng.now();
    batch.run(&mut cl, &mut eng)?;
    let gather_ns = eng.now() - t0;

    // Pull only the pooled results back and verify every lane.
    let out = client.read(&mut cl, &mut eng, results.gva, N_BAGS * ROW_BYTES)?;
    for (b, want) in expect.iter().enumerate() {
        let lanes = bytes_to_f32s(&out[b * ROW_BYTES..(b + 1) * ROW_BYTES])?;
        assert!(
            lanes.iter().all(|&v| v == *want),
            "bag {b}: expected {want}, got {:?}...",
            &lanes[..4]
        );
    }
    let naive = N_BAGS * BAG * ROW_BYTES;
    let pulled = N_BAGS * ROW_BYTES;
    println!(
        "{N_BAGS} bags x {BAG} rows gathered in {} (one pipelined batch) — host pulled {pulled} B instead of {naive} B ({}x reduction) ✓",
        fmt_ns(gather_ns),
        naive / pulled
    );
    Ok(())
}
