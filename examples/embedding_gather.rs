//! Embedding-bag gather on the pooled memory plane (TensorDIMM-style
//! near-memory reduction) — driven through the session API.
//!
//! A recommendation model's embedding table lives sharded across the
//! NetDAM pool (block interleaving spreads rows over every device). For
//! each lookup *bag* (a sparse set of row indices), the host does not
//! pull every row over the network: `MemBatch::gather_sum` compiles the
//! bag into ONE self-routing packet program that visits each row's
//! device, folds the row into the packet accumulator with an on-device
//! `Simd` add, and writes the pooled sum into a result slot — only the
//! result row ever crosses the host link, a `bag_size:1` traffic
//! reduction exactly like TensorDIMM's near-memory embedding lookups.
//!
//! Since PR 5 the example holds a [`netdam::comm::Fabric`]: the
//! controller, topology and windowed engine come from one builder, the
//! tenant client from [`Fabric::mem_client`], and the bag batch is
//! submitted onto the fabric's **shared** session — the same engine a
//! concurrent training job's collectives would multiplex onto.
//!
//! ```sh
//! cargo run --release --example embedding_gather
//! ```

use anyhow::Result;
use netdam::comm::Fabric;
use netdam::sim::fmt_ns;
use netdam::util::bytes::{bytes_to_f32s, f32s_to_bytes};
use netdam::util::Xoshiro256;

const ROW_F32: usize = 256; // 1 KiB rows: 8 per interleave block
const ROW_BYTES: usize = ROW_F32 * 4;
const N_ROWS: usize = 512; // 512 KiB table
const N_BAGS: usize = 16;
const BAG: usize = 4;

fn main() -> Result<()> {
    println!("== Embedding-bag gather: near-memory reduce over the pool ==\n");
    let mut fabric = Fabric::builder()
        .star(4)
        .hosts(1)
        .seed(0xE1B)
        .with_pool(1 << 20)
        .build()?;
    let client = fabric.mem_client()?;
    let tenant = client.tenant;

    // Lease the table + result slots; the controller programs the IOMMUs.
    let table = fabric.malloc(tenant, (N_ROWS * ROW_BYTES) as u64, true)?;
    let results = fabric.malloc(tenant, (N_BAGS * ROW_BYTES) as u64, true)?;

    // Populate the table: row r = [r, r, ...] (easy to verify sums).
    let mut bytes = Vec::with_capacity(N_ROWS * ROW_BYTES);
    for r in 0..N_ROWS {
        bytes.extend_from_slice(&f32s_to_bytes(&vec![r as f32; ROW_F32]));
    }
    fabric.mem_write(&client, table.gva, &bytes)?;
    println!(
        "table: {} rows x {} f32 sharded over {} devices",
        N_ROWS,
        ROW_F32,
        client.map().n_devices()
    );

    // Random bags; each gathers BAG rows near memory. All bags ride ONE
    // pipelined batch on the fabric session: every bag's program is in
    // flight at once under the per-device windows.
    let mut rng = Xoshiro256::seed_from(0xBA6);
    let mut expect = Vec::with_capacity(N_BAGS);
    let mut batch = client.batch();
    for b in 0..N_BAGS {
        let rows: Vec<u64> = (0..BAG).map(|_| rng.next_below(N_ROWS as u64)).collect();
        let gvas: Vec<u64> = rows
            .iter()
            .map(|&r| table.gva + r * ROW_BYTES as u64)
            .collect();
        let dst = results.gva + (b * ROW_BYTES) as u64;
        batch
            .gather_sum(fabric.cluster_mut(), &gvas, ROW_BYTES, dst)?;
        expect.push(rows.iter().sum::<u64>() as f32);
    }
    let t0 = fabric.now();
    let h = fabric.submit_mem(batch)?;
    fabric.wait_mem(h)?;
    let gather_ns = fabric.now() - t0;

    // Pull only the pooled results back and verify every lane.
    let out = fabric.mem_read(&client, results.gva, N_BAGS * ROW_BYTES)?;
    for (b, want) in expect.iter().enumerate() {
        let lanes = bytes_to_f32s(&out[b * ROW_BYTES..(b + 1) * ROW_BYTES])?;
        assert!(
            lanes.iter().all(|&v| v == *want),
            "bag {b}: expected {want}, got {:?}...",
            &lanes[..4]
        );
    }
    let naive = N_BAGS * BAG * ROW_BYTES;
    let pulled = N_BAGS * ROW_BYTES;
    println!(
        "{N_BAGS} bags x {BAG} rows gathered in {} (one pipelined batch) — host pulled {pulled} B instead of {naive} B ({}x reduction) ✓",
        fmt_ns(gather_ns),
        naive / pulled
    );
    Ok(())
}
