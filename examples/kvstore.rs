//! A KV store on the **pooled memory plane** (paper §2.4–§2.6): the SDN
//! controller leases lock and value regions out of the block-interleaved
//! global pool and programs every device IOMMU with the lease; the store
//! then runs entirely on global virtual addresses through `MemClient` —
//! a CAS word serializes writers (the paper's atomic-instruction
//! pattern), values spray across all devices via scatter-gather WRITEs,
//! and a foreign tenant is fenced *by the devices themselves*: its reads
//! come back as wire-level NAKs, not host-side errors.
//!
//! ```sh
//! cargo run --release --example kvstore
//! ```

use anyhow::Result;
use netdam::mem::{MemClient, MemError};
use netdam::net::{Cluster, LinkConfig, Topology};
use netdam::pool::{InterleaveMap, SdnController, TenantId};
use netdam::sim::Engine;
use netdam::util::bytes::{bytes_to_f32s, f32s_to_bytes};
use netdam::wire::DeviceIp;

const SLOT_BYTES: u64 = 256;
// 128 slots x 256 B = 4 interleave blocks: the value region genuinely
// spans every device of the 4-wide pool.
const N_KEYS: u64 = 128;
const KV_TENANT: TenantId = 1;

struct Kv {
    client: MemClient,
    /// GVA of the lock word region (one u64 per key).
    locks: u64,
    /// GVA of the value region (one slot per key).
    data: u64,
}

impl Kv {
    fn slot(&self, key: u64) -> (u64, u64) {
        (self.locks + key * 8, self.data + key * SLOT_BYTES)
    }

    /// CAS-acquire the slot lock, scatter the value over the pool,
    /// release the lock. Returns false if another writer holds the lock.
    fn put(
        &self,
        cl: &mut Cluster,
        eng: &mut Engine<Cluster>,
        key: u64,
        value: &[f32],
    ) -> Result<bool> {
        let (lock, slot) = self.slot(key);
        let (_, acquired) = self.client.cas(cl, eng, lock, 0, 1)?;
        if !acquired {
            return Ok(false); // contended
        }
        self.client.write(cl, eng, slot, &f32s_to_bytes(value))?;
        let (_, released) = self.client.cas(cl, eng, lock, 1, 0)?;
        assert!(released, "lock holder always releases");
        Ok(true)
    }

    fn get(
        &self,
        cl: &mut Cluster,
        eng: &mut Engine<Cluster>,
        key: u64,
        len: usize,
    ) -> Result<Vec<f32>> {
        let (_, slot) = self.slot(key);
        let bytes = self.client.read(cl, eng, slot, len * 4)?;
        bytes_to_f32s(&bytes)
    }
}

fn main() -> Result<()> {
    println!("== KV store on the pooled memory plane ==\n");
    // The paper testbed (4 devices, one ToR) plus a second host that will
    // play the intruder.
    let t = Topology::star(11, 4, 2, LinkConfig::dc_100g());
    let mut cl = t.cluster;
    let mut eng: Engine<Cluster> = Engine::new();

    // Control plane: the SDN controller leases the store's regions and
    // programs every device IOMMU (malloc → map + perms + tenant fence).
    let map = InterleaveMap::paper_default((1..=4).map(DeviceIp::lan).collect());
    let mut ctl = SdnController::new(map, 2 << 30);
    ctl.grant_host(&mut cl, KV_TENANT, DeviceIp::lan(101));
    let locks = ctl.malloc_mapped(&mut cl, KV_TENANT, N_KEYS * 8, true)?;
    let data = ctl.malloc_mapped(&mut cl, KV_TENANT, N_KEYS * SLOT_BYTES, true)?;
    println!(
        "leases: locks at gva {:#x} (+{}), values at gva {:#x} (+{})",
        locks.gva, locks.len, data.gva, data.len
    );
    let kv = Kv {
        client: MemClient::new(t.hosts[0], DeviceIp::lan(101), KV_TENANT, ctl.map().clone()),
        locks: locks.gva,
        data: data.gva,
    };

    let v1: Vec<f32> = (0..32).map(|i| i as f32 * 1.5).collect();
    assert!(kv.put(&mut cl, &mut eng, 3, &v1)?);
    println!("PUT key=3 (32 x f32, scatter-gathered over the pool)");

    let got = kv.get(&mut cl, &mut eng, 3, 32)?;
    assert_eq!(got, v1, "value reassembles in GVA order");
    println!("GET key=3 == written value ✓");

    // The slot genuinely interleaves: the controller's translation shows
    // the value region spread over every device.
    let extents = ctl.access(KV_TENANT, data.gva, data.len, false)?;
    let devs: std::collections::BTreeSet<_> = extents.iter().map(|e| e.device).collect();
    println!("value region interleaves over {} devices", devs.len());
    assert_eq!(devs.len(), 4);

    // Lock contention: a second writer fails the CAS while locked.
    let (lock9, _) = kv.slot(9);
    let (_, held) = kv.client.cas(&mut cl, &mut eng, lock9, 0, 1)?;
    assert!(held);
    let stole = kv.put(&mut cl, &mut eng, 9, &v1)?;
    println!("second writer while locked: put accepted = {stole} (expected false)");
    assert!(!stole);

    // Device-enforced ACL: an intruder host (never granted) reads the
    // value region — the *device IOMMU* rejects it with a wire NAK.
    let intruder = MemClient::new(t.hosts[1], DeviceIp::lan(102), 9, kv.client.map().clone());
    match intruder.read(&mut cl, &mut eng, data.gva, 64) {
        Err(MemError::Nak { device, reason, .. }) => {
            println!("intruder read NAK'd by device {device}: {reason}")
        }
        other => panic!("expected a device NAK, got {other:?}"),
    }

    println!("\nfabric counters:");
    print!("{}", cl.metrics.render());
    Ok(())
}
