//! A multi-tenant KV/embedding store on the **pooled memory plane**
//! (paper §2.4–§2.6), driven through the serving tier (`netdam::serve`):
//! every tenant gets leases out of the block-interleaved global pool and
//! a private seeded request stream — Zipf-skewed GET/PUT/CAS plus
//! TensorDIMM-style embedding bags lowered onto near-memory `gather_sum`
//! programs — all contending on ONE fabric. The devices themselves fence
//! tenants: when an aggressor replays plans against a lease the SDN
//! controller already revoked, every access dies as a wire-level NAK and
//! per-plan cancellation while its neighbors' schedules complete
//! untouched.
//!
//! ```sh
//! cargo run --release --example kvstore
//! ```

use anyhow::{ensure, Result};
use netdam::comm::Fabric;
use netdam::serve::{run, Mix, ServeConfig};
use netdam::sim::fmt_ns;

fn main() -> Result<()> {
    println!("== multi-tenant KV/embedding store on the pooled memory plane ==\n");

    // Value integrity first, outside the statistics: one tenant, one
    // key, a put/get round trip through the interleaved pool.
    let mut fabric = Fabric::builder()
        .star(4)
        .hosts(1)
        .seed(7)
        .with_pool(4 << 20)
        .build()?;
    let client = fabric.mem_client()?;
    let lease = fabric.malloc(client.tenant, 16 * 512, true)?;
    let value: Vec<u8> = (0..512u32).map(|i| (i as u8).wrapping_mul(29)).collect();
    let mut b = client.batch();
    b.write(fabric.cluster_mut(), lease.gva + 3 * 512, &value);
    let h = fabric.submit_mem(b)?;
    fabric.wait_mem(h)?;
    let mut b = client.batch();
    let rb = b.read(fabric.cluster_mut(), lease.gva + 3 * 512, value.len());
    let h = fabric.submit_mem(b)?;
    let mut out = fabric.wait_mem(h)?;
    ensure!(
        out.take_read(rb).as_deref() == Some(&value[..]),
        "value must reassemble in GVA order"
    );
    println!("PUT/GET key round-trips through the interleaved pool ✓\n");

    // The fleet: three tenants with Zipf(0.99) keys and the serving mix
    // (GET/PUT/CAS + embedding bags), scratch leases churning under live
    // traffic, and a fourth, misbehaving tenant running alongside — a
    // NAK storm from a revoked lease plus an incast burst.
    let cfg = ServeConfig {
        tenants: 3,
        devices: 4,
        keys_per_tenant: 128,
        value_bytes: 512,
        waves: 3,
        ops_per_wave: 16,
        skew: 0.99,
        mix: Mix::serving_default(),
        aggressor: true,
        seed: 0x570_4E5E,
        ..Default::default()
    };
    let report = run(&cfg)?;
    print!("{}", report.render());

    let agg = report.aggressor.as_ref().expect("aggressor ran");
    ensure!(
        agg.naks > 0 && agg.cancelled > 0,
        "the storm must die as device NAKs + cancellation"
    );
    for t in &report.tenants {
        ensure!(
            t.naks == 0 && t.done == t.ops,
            "a neighbor's schedule was disturbed"
        );
    }
    println!(
        "\naggressor fenced by the devices ({} NAKs, {} ops cancelled); \
         neighbors NAK-free, worst p99 {}",
        agg.naks,
        agg.cancelled,
        fmt_ns(report.worst_p99())
    );
    Ok(())
}
