//! A replicated KV store on raw NetDAM instructions — the "RPC-like"
//! programming model of §2.4: clients talk straight to device memory
//! with WRITE / READ / CAS; a CAS word serializes writers (the paper's
//! atomic-instruction-as-idempotent-operator pattern); values replicate
//! to a second device through an SROU-chained write.
//!
//! ```sh
//! cargo run --release --example kvstore
//! ```

use anyhow::Result;
use netdam::isa::{Flags, Instruction};
use netdam::net::{Cluster, LinkConfig, NodeId, Topology};
use netdam::sim::{fmt_ns, Engine};
use netdam::util::bytes::{bytes_to_f32s, f32s_to_bytes};
use netdam::wire::{DeviceIp, Packet, Payload, Segment, SrouHeader};

const SLOT_BYTES: u64 = 256;
const LOCK_BASE: u64 = 0;
const DATA_BASE: u64 = 1 << 20;

struct Kv {
    host: NodeId,
    host_ip: DeviceIp,
    primary: DeviceIp,
    replica: DeviceIp,
}

impl Kv {
    fn slot(key: u64) -> (u64, u64) {
        (LOCK_BASE + key * 8, DATA_BASE + key * SLOT_BYTES)
    }

    /// CAS-acquire the slot lock, write value to primary + replica
    /// (chained), release the lock.
    fn put(&self, cl: &mut Cluster, eng: &mut Engine<Cluster>, key: u64, value: &[f32]) -> Result<bool> {
        let (lock, data) = Self::slot(key);
        // 1. acquire
        let seq = cl.alloc_seq(self.host);
        let cas = Packet::new(self.host_ip, seq, SrouHeader::direct(self.primary), Instruction::Cas {
            addr: lock,
            expected: 0,
            new: 1,
        });
        cl.inject(eng, self.host, cas);
        eng.run(cl);
        let (_, resp) = cl.host_mut(self.host).mailbox.pop().unwrap();
        let Instruction::CasResp { swapped: true, .. } = resp.instr else {
            return Ok(false); // contended
        };
        // 2. replicated write: a 2-hop store program writes the value at
        //    the primary, then self-routes to the replica.
        let seq = cl.alloc_seq(self.host);
        let prog = netdam::isa::ProgramBuilder::new()
            .store(data, 2)
            .build_unchecked();
        let w = Packet::new(
            self.host_ip,
            seq,
            SrouHeader::through(vec![Segment::to(self.primary), Segment::to(self.replica)]),
            Instruction::Program(Box::new(prog)),
        )
        .with_payload(Payload::from_bytes(f32s_to_bytes(value)));
        cl.inject(eng, self.host, w);
        eng.run(cl);
        // 3. release
        let seq = cl.alloc_seq(self.host);
        let rel = Packet::new(self.host_ip, seq, SrouHeader::direct(self.primary), Instruction::Cas {
            addr: lock,
            expected: 1,
            new: 0,
        });
        cl.inject(eng, self.host, rel);
        eng.run(cl);
        cl.host_mut(self.host).mailbox.clear();
        Ok(true)
    }

    fn get(&self, cl: &mut Cluster, eng: &mut Engine<Cluster>, key: u64, len: usize, from_replica: bool) -> Result<Vec<f32>> {
        let (_, data) = Self::slot(key);
        let target = if from_replica { self.replica } else { self.primary };
        let seq = cl.alloc_seq(self.host);
        let r = Packet::new(self.host_ip, seq, SrouHeader::direct(target), Instruction::Read {
            addr: data,
            len: (len * 4) as u32,
        });
        cl.inject(eng, self.host, r);
        eng.run(cl);
        let (t, resp) = cl.host_mut(self.host).mailbox.pop().unwrap();
        println!(
            "  GET key={key} from {} -> {} at {}",
            if from_replica { "replica" } else { "primary" },
            len,
            fmt_ns(t)
        );
        bytes_to_f32s(resp.payload.bytes().unwrap())
    }
}

fn main() -> Result<()> {
    println!("== KV store over raw NetDAM instructions ==\n");
    let t = Topology::paper_testbed(11);
    let mut cl = t.cluster;
    let mut eng: Engine<Cluster> = Engine::new();
    let kv = Kv {
        host: t.hosts[0],
        host_ip: DeviceIp::lan(101),
        primary: DeviceIp::lan(1),
        replica: DeviceIp::lan(2),
    };

    let v1: Vec<f32> = (0..32).map(|i| i as f32 * 1.5).collect();
    assert!(kv.put(&mut cl, &mut eng, 3, &v1)?);
    println!("PUT key=3 (32 x f32, replicated via SROU chain)");

    let got_p = kv.get(&mut cl, &mut eng, 3, 32, false)?;
    let got_r = kv.get(&mut cl, &mut eng, 3, 32, true)?;
    assert_eq!(got_p, v1);
    assert_eq!(got_r, v1, "replica consistent through the chained write");
    println!("primary == replica == written value ✓");

    // Lock contention: a second writer fails CAS while locked.
    let seq = cl.alloc_seq(kv.host);
    let hold = Packet::new(kv.host_ip, seq, SrouHeader::direct(kv.primary), Instruction::Cas {
        addr: Kv::slot(9).0,
        expected: 0,
        new: 1,
    });
    cl.inject(&mut eng, kv.host, hold);
    eng.run(&mut cl);
    cl.host_mut(kv.host).mailbox.clear();
    let stole = kv.put(&mut cl, &mut eng, 9, &v1)?;
    println!("second writer while locked: put accepted = {stole} (expected false)");
    assert!(!stole);

    println!("\nfabric counters:");
    print!("{}", cl.metrics.render());
    let _ = LinkConfig::dc_100g();
    Ok(())
}
