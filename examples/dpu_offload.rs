//! DPU offload on the programmable ISA (paper §2.4/§2.6): encryption-
//! write / decryption-read, CRC, RLE compression and LPM lookup execute
//! *inside* the NetDAM device, reached as user-defined instructions over
//! the same packet format as READ/WRITE.
//!
//! ```sh
//! cargo run --release --example dpu_offload
//! ```

use anyhow::Result;
use std::sync::Arc;

use netdam::isa::dpu::{
    register_dpu_instructions, OP_CRC32, OP_CRYPTO_READ, OP_CRYPTO_WRITE, OP_LPM_LOOKUP,
};
use netdam::isa::registry::{InstructionRegistry, MemAccess};
use netdam::isa::Instruction;
use netdam::net::{Cluster, LinkConfig, Switch};
use netdam::sim::{fmt_ns, Engine};
use netdam::wire::{DeviceIp, Packet, Payload, SrouHeader};

fn main() -> Result<()> {
    println!("== DPU offload instructions on NetDAM ==\n");

    // Flash the DPU instruction library into every device in the cluster.
    let mut reg = InstructionRegistry::new();
    register_dpu_instructions(&mut reg, 0x5EC0_0E7)?;
    let mut cl = Cluster::with_registry(21, Arc::new(reg));
    let sw = cl.add_switch(Switch::tor(None));
    let host = cl.add_host(DeviceIp::lan(101), None);
    let dev = cl.add_device(netdam::device::DeviceConfig::paper_default(DeviceIp::lan(1)));
    cl.connect(sw, host, LinkConfig::dc_100g());
    cl.connect(sw, dev, LinkConfig::dc_100g());
    cl.compute_routes();
    let mut eng: Engine<Cluster> = Engine::new();
    let host_ip = DeviceIp::lan(101);
    let dst = DeviceIp::lan(1);

    let mut call = |cl: &mut Cluster,
                    eng: &mut Engine<Cluster>,
                    opcode: u16,
                    a: u64,
                    b: u64,
                    c: u64,
                    payload: Vec<u8>|
     -> (u64, Instruction, Payload) {
        let seq = cl.alloc_seq(host);
        let pkt = Packet::new(
            host_ip,
            seq,
            SrouHeader::direct(dst),
            Instruction::User { opcode, a, b, c },
        )
        .with_payload(Payload::from_bytes(payload));
        cl.inject(eng, host, pkt);
        eng.run(cl);
        let (t, resp) = cl.host_mut(host).mailbox.pop().expect("reply");
        (t, resp.instr, resp.payload)
    };

    // 1. encryption-write: plaintext goes in, ciphertext lands in HBM.
    let secret = b"multi-terabyte memory pool, now with secrecy".to_vec();
    let (t, _, _) = call(&mut cl, &mut eng, OP_CRYPTO_WRITE, 0x1000, 0, 0, secret.clone());
    let in_memory = cl.device_mut(dev).mem().read(0x1000, secret.len())?;
    println!("crypto-write at {}: memory holds ciphertext: {}", fmt_ns(t), in_memory != secret);

    // 2. decryption-read returns the plaintext.
    let (t, _, payload) = call(
        &mut cl,
        &mut eng,
        OP_CRYPTO_READ,
        0x1000,
        secret.len() as u64,
        0,
        vec![],
    );
    assert_eq!(payload.bytes().unwrap(), &secret[..]);
    println!("crypto-read at {}: plaintext recovered ✓", fmt_ns(t));

    // 3. CRC-32 near memory.
    cl.device_mut(dev).mem().write(0x2000, b"123456789")?;
    let (_, instr, _) = call(&mut cl, &mut eng, OP_CRC32, 0x2000, 9, 0, vec![]);
    let Instruction::User { c: crc, .. } = instr else { panic!() };
    println!("crc32(\"123456789\") in-device = {crc:#010x} (expect 0xcbf43926)");
    assert_eq!(crc, 0xCBF4_3926);

    // 4. LPM: a routing table in device memory, looked up remotely.
    let mut table = Vec::new();
    for (prefix, plen, hop) in [([10u8, 0, 0, 0], 8u32, 1u32), ([10, 9, 0, 0], 16, 7)] {
        table.extend_from_slice(&u32::from_be_bytes(prefix).to_le_bytes());
        table.extend_from_slice(&plen.to_le_bytes());
        table.extend_from_slice(&hop.to_le_bytes());
    }
    cl.device_mut(dev).mem().write(0x3000, &table)?;
    let ip = u32::from_be_bytes([10, 9, 1, 2]) as u64;
    let (_, instr, _) = call(&mut cl, &mut eng, OP_LPM_LOOKUP, 0x3000, 2, ip, vec![]);
    let Instruction::User { c: hop, .. } = instr else { panic!() };
    println!("lpm(10.9.1.2) -> next hop {hop} (expect 7)");
    assert_eq!(hop, 7);

    println!("\nall DPU offloads executed in-device over the NetDAM wire ✓");
    Ok(())
}
