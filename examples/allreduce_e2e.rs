//! The paper's headline experiment (§3.3), end to end — on the session
//! API.
//!
//! Builds ONE [`netdam::comm::Fabric`] (topology + registry + shared
//! window engine), derives a tenant [`netdam::comm::Communicator`], and
//! runs the 4-node allreduce through it: the NetDAM in-memory ring
//! verified bit-exactly against the host oracle, then the §3.3
//! comparison table (ring over RoCE hosts, native-MPI recursive
//! doubling) and the full algorithm menu on the same grid. Two modes:
//!
//! ```sh
//! cargo run --release --example allreduce_e2e                 # data-bearing, verified
//! NETDAM_PAPER_SCALE=1 cargo run --release --example allreduce_e2e   # 2^29 floats, timing
//! ```
//!
//! In data-bearing mode every device's final memory is compared against
//! the ring-order oracle — the numbers that cross the simulated wire are
//! the numbers that land.

use anyhow::Result;
use netdam::collectives::{oracle_sum, run_collective, AlgoKind, RunOpts};
use netdam::comm::Fabric;
use netdam::coordinator::{run_e2, E2Config};
use netdam::metrics::Table;
use netdam::sim::fmt_ns;

fn main() -> Result<()> {
    let paper_scale = std::env::var("NETDAM_PAPER_SCALE").is_ok();
    let (elements, timing_only) = if paper_scale {
        (536_870_912usize, true) // the paper's 2 GiB vector
    } else {
        (1 << 20, false)
    };

    println!("== E2: MPI allreduce, 4 nodes, 100G (paper §3.3) ==");
    println!(
        "vector: {} x f32 ({:.1} MiB), mode: {}\n",
        elements,
        elements as f64 * 4.0 / (1 << 20) as f64,
        if timing_only { "timing-only (paper scale)" } else { "data-bearing (verified)" }
    );

    // --- correctness first: data-bearing verification run --------------
    // One Fabric, one Communicator, one blocking allreduce — the session
    // API's smallest program.
    if !timing_only {
        let mut fabric = Fabric::builder().star(4).seed(7).build()?;
        let comm = fabric.communicator(elements as u64 * 4)?;
        let grads = comm.seed_gradients(&mut fabric, elements, 99);
        let out = comm.allreduce(&mut fabric, elements)?;
        anyhow::ensure!(out.complete(), "allreduce stopped short");
        let oracle = oracle_sum(&grads);
        let mut exact = true;
        for r in 0..4 {
            exact &= comm.read_vector(&mut fabric, r, elements)? == oracle;
        }
        println!(
            "verification: {} chunk programs, all devices bit-exact vs oracle: {exact}",
            out.ops
        );
        assert!(exact, "allreduce numerics diverged from the oracle");
        println!(
            "NetDAM allreduce of {} f32: {} (window {})\n",
            elements,
            fmt_ns(out.elapsed_ns()),
            16
        );
    }

    // --- the §3.3 table (device arms ride shared fabrics inside) -------
    let cfg = E2Config {
        elements,
        ranks: 4,
        timing_only: true, // comparison arms always run timing payloads
        window: 32,
        seed: 0xE2E2,
        with_baselines: true,
        ..Default::default()
    };
    let r = run_e2(&cfg)?;
    print!("{}", r.table.render());
    println!(
        "\nspeedup vs ring-RoCE: {:.2}x (paper: ~5.3x) | vs native MPI: {:.2}x (paper: 7x)",
        r.ring_roce_ns as f64 / r.netdam_ns as f64,
        r.mpi_native_ns as f64 / r.netdam_ns as f64,
    );
    println!(
        "NetDAM vs line-rate floor: {:.2}x",
        r.netdam_ns as f64 / r.line_rate_floor_ns as f64
    );

    // --- the unified engine's algorithm menu ----------------------------
    if !paper_scale {
        println!("\n== collective menu (shared driver, same grid) ==\n");
        let mut table = Table::new(&["algorithm", "time", "bus bw (Gbit/s)"]);
        for kind in AlgoKind::ALL {
            // The paper triple already ran inside run_e2 with identical
            // parameters — reuse those reports instead of re-simulating.
            let rep = match r.reports.iter().find(|rep| rep.algorithm == kind.name()) {
                Some(rep) => rep.clone(),
                None => run_collective(
                    kind,
                    &RunOpts {
                        elements,
                        ranks: 4,
                        seed: 0xE2E2,
                        window: 32,
                        timing_only: true,
                        ..Default::default()
                    },
                )?,
            };
            table.row(&[
                rep.algorithm.to_string(),
                fmt_ns(rep.elapsed_ns),
                format!("{:.1}", rep.bus_bw_gbps(kind.bw_fraction(4))),
            ]);
        }
        print!("{}", table.render());
        println!("\n(select on the CLI with `netdam allreduce --algo <list|all>`;");
        println!(" overlapping multi-tenant jobs: `netdam comm`)");
    }
    Ok(())
}
