//! The paper's headline experiment (§3.3), end to end.
//!
//! Runs the 4-node allreduce through the unified collective engine —
//! the NetDAM in-memory ring, the Horovod-style ring over RoCE hosts,
//! and native-MPI recursive doubling — prints the §3.3 comparison table,
//! then sweeps the full algorithm menu (halving-doubling, hierarchical
//! two-level, and the standalone primitives) on the same grid. Two modes:
//!
//! ```sh
//! cargo run --release --example allreduce_e2e                 # data-bearing, verified
//! NETDAM_PAPER_SCALE=1 cargo run --release --example allreduce_e2e   # 2^29 floats, timing
//! ```
//!
//! In data-bearing mode every device's final memory is compared against
//! the ring-order oracle — the numbers that cross the simulated wire are
//! the numbers that land.

use anyhow::Result;
use netdam::collectives::{
    oracle_sum, read_vector, run_collective, run_ring_allreduce, seed_gradients, AlgoKind,
    RingSpec, RunOpts,
};
use netdam::coordinator::{run_e2, E2Config};
use netdam::metrics::Table;
use netdam::net::{Cluster, LinkConfig, Topology};
use netdam::sim::{fmt_ns, Engine};

fn main() -> Result<()> {
    let paper_scale = std::env::var("NETDAM_PAPER_SCALE").is_ok();
    let (elements, timing_only) = if paper_scale {
        (536_870_912usize, true) // the paper's 2 GiB vector
    } else {
        (1 << 20, false)
    };

    println!("== E2: MPI allreduce, 4 nodes, 100G (paper §3.3) ==");
    println!(
        "vector: {} x f32 ({:.1} MiB), mode: {}\n",
        elements,
        elements as f64 * 4.0 / (1 << 20) as f64,
        if timing_only { "timing-only (paper scale)" } else { "data-bearing (verified)" }
    );

    // --- correctness first: data-bearing verification run --------------
    if !timing_only {
        let t = Topology::star(7, 4, 0, LinkConfig::dc_100g());
        let mut cl = t.cluster;
        let devices = t.devices;
        let grads = seed_gradients(&mut cl, &devices, elements, 0, 99);
        let mut eng: Engine<Cluster> = Engine::new();
        let out = run_ring_allreduce(
            &mut cl,
            &mut eng,
            &devices,
            &RingSpec {
                elements,
                ..Default::default()
            },
        )?;
        let oracle = oracle_sum(&grads);
        let mut exact = true;
        for &d in &devices {
            let got = read_vector(&mut cl, d, 0, elements)?;
            exact &= got == oracle;
        }
        println!(
            "verification: {} blocks, all devices bit-exact vs oracle: {exact}",
            out.blocks
        );
        assert!(exact, "allreduce numerics diverged from the oracle");
        println!(
            "NetDAM allreduce of {} f32: {} (window {})\n",
            elements,
            fmt_ns(out.elapsed_ns),
            16
        );
    }

    // --- the §3.3 table -------------------------------------------------
    let cfg = E2Config {
        elements,
        ranks: 4,
        timing_only: true, // comparison arms always run timing payloads
        window: 32,
        seed: 0xE2E2,
        with_baselines: true,
        ..Default::default()
    };
    let r = run_e2(&cfg)?;
    print!("{}", r.table.render());
    println!(
        "\nspeedup vs ring-RoCE: {:.2}x (paper: ~5.3x) | vs native MPI: {:.2}x (paper: 7x)",
        r.ring_roce_ns as f64 / r.netdam_ns as f64,
        r.mpi_native_ns as f64 / r.netdam_ns as f64,
    );
    println!(
        "NetDAM vs line-rate floor: {:.2}x",
        r.netdam_ns as f64 / r.line_rate_floor_ns as f64
    );

    // --- the unified engine's algorithm menu ----------------------------
    if !paper_scale {
        println!("\n== collective menu (shared driver, same grid) ==\n");
        let mut table = Table::new(&["algorithm", "time", "bus bw (Gbit/s)"]);
        for kind in AlgoKind::ALL {
            // The paper triple already ran inside run_e2 with identical
            // parameters — reuse those reports instead of re-simulating.
            let rep = match r.reports.iter().find(|rep| rep.algorithm == kind.name()) {
                Some(rep) => rep.clone(),
                None => run_collective(
                    kind,
                    &RunOpts {
                        elements,
                        ranks: 4,
                        seed: 0xE2E2,
                        window: 32,
                        timing_only: true,
                        ..Default::default()
                    },
                )?,
            };
            table.row(&[
                rep.algorithm.to_string(),
                fmt_ns(rep.elapsed_ns),
                format!("{:.1}", rep.bus_bw_gbps(kind.bw_fraction(4))),
            ]);
        }
        print!("{}", table.render());
        println!("\n(select on the CLI with `netdam allreduce --algo <list|all>`)");
    }
    Ok(())
}
