//! Quickstart: build the paper's 4-device testbed, touch remote memory
//! with the core ISA (WRITE / READ / CAS / SIMD), and print what each
//! operation cost on the simulated wire.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use anyhow::Result;
use netdam::isa::{Flags, Instruction, SimdOp};
use netdam::net::{Cluster, LinkConfig, Topology};
use netdam::sim::{fmt_ns, Engine};
use netdam::wire::{DeviceIp, Packet, Payload, SrouHeader};

fn main() -> Result<()> {
    // The testbed of §3: 4 NetDAM devices + a driver host on one switch.
    let t = Topology::paper_testbed(42);
    let mut cl = t.cluster;
    let host = t.hosts[0];
    let host_ip = DeviceIp::lan(101);
    let dev1 = DeviceIp::lan(1);
    let mut eng: Engine<Cluster> = Engine::new();

    println!("== NetDAM quickstart: 4 devices + host on a 100G switch ==\n");

    // 1. WRITE 2048 f32 (one SIMD block) into device 1, reliable.
    let payload: Vec<f32> = (0..2048).map(|i| i as f32).collect();
    let seq = cl.alloc_seq(host);
    let w = Packet::new(host_ip, seq, SrouHeader::direct(dev1), Instruction::Write {
        addr: 0x1_0000,
    })
    .with_flags(Flags(Flags::RELIABLE))
    .with_payload(Payload::from_f32s(&payload));
    println!("WRITE 8 KiB -> {dev1}  ({} B on the wire)", w.wire_bytes());
    cl.inject(&mut eng, host, w);
    eng.run(&mut cl);
    report(&mut cl, host, "WRITE ack");

    // 2. READ 32 x f32 back (the E1 request).
    let seq = cl.alloc_seq(host);
    let r = Packet::new(host_ip, seq, SrouHeader::direct(dev1), Instruction::Read {
        addr: 0x1_0000,
        len: 128,
    });
    cl.inject(&mut eng, host, r);
    eng.run(&mut cl);
    let (t_resp, resp) = cl.host_mut(host).mailbox.pop().unwrap();
    let values = resp.payload.f32s().unwrap()?;
    println!(
        "READ 32 x f32  -> {:?}... at {}",
        &values[..4],
        fmt_ns(t_resp)
    );

    // 3. CAS: an atomic lock word (the idempotent-operator building block).
    for (expected, new, label) in [(0u64, 7, "acquire"), (0, 9, "contended")] {
        let seq = cl.alloc_seq(host);
        let cas = Packet::new(host_ip, seq, SrouHeader::direct(dev1), Instruction::Cas {
            addr: 0x2_0000,
            expected,
            new,
        });
        cl.inject(&mut eng, host, cas);
        eng.run(&mut cl);
        let (_, resp) = cl.host_mut(host).mailbox.pop().unwrap();
        if let Instruction::CasResp { swapped, old, .. } = resp.instr {
            println!("CAS {label}: swapped={swapped} old={old}");
        }
    }

    // 4. SIMD ADD against remote memory: one instruction, 2048 lanes.
    let addend: Vec<f32> = vec![0.5; 2048];
    let seq = cl.alloc_seq(host);
    let simd = Packet::new(host_ip, seq, SrouHeader::direct(dev1), Instruction::Simd {
        op: SimdOp::Add,
        addr: 0x1_0000,
    })
    .with_payload(Payload::from_f32s(&addend));
    cl.inject(&mut eng, host, simd);
    eng.run(&mut cl);
    let (_, resp) = cl.host_mut(host).mailbox.pop().unwrap();
    let sums = resp.payload.f32s().unwrap()?;
    println!(
        "SIMD ADD 2048 lanes near memory -> [{}, {}, {}, ...]",
        sums[0], sums[1], sums[2]
    );
    assert_eq!(sums[3], 3.5);

    // 5. A chained computation as a packet *program*: add device 2's
    //    block into the payload, then guarded-write the result at
    //    device 3 (SROU chaining + the programmable ISA in one packet).
    let seq = cl.alloc_seq(host);
    use netdam::isa::ProgramBuilder;
    use netdam::wire::Segment;
    let prog = ProgramBuilder::new()
        .reduce(SimdOp::Add, 0x3_0000, 2)
        .guarded_write(0x3_0000, netdam::alu::block_hash(&[0u8; 8192]))
        .on_retire(0)
        .build_unchecked();
    let chain = Packet::new(
        host_ip,
        seq,
        SrouHeader::through(vec![Segment::to(DeviceIp::lan(2)), Segment::to(DeviceIp::lan(3))]),
        Instruction::Program(Box::new(prog)),
    )
    .with_payload(Payload::from_f32s(&vec![1.0f32; 2048]));
    cl.inject(&mut eng, host, chain);
    eng.run(&mut cl);
    println!(
        "program chain dev2 -> dev3 completed ({} completions logged)",
        cl.completions.len()
    );

    println!("\nfabric counters:");
    print!("{}", cl.metrics.render());
    Ok(())
}

fn report(cl: &mut Cluster, host: netdam::net::NodeId, what: &str) {
    if let Some((t, _)) = cl.host_mut(host).mailbox.pop() {
        println!("{what} at {}", fmt_ns(t));
    }
    let _ = LinkConfig::dc_100g(); // keep the import obviously used
}
