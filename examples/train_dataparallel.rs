//! End-to-end driver: data-parallel MLP training where every gradient
//! allreduce runs through the simulated NetDAM fabric.
//!
//! The three layers compose here:
//! * **L1/L2** — the `mlp_grad` / `sgd_apply` / `mlp_batch` HLO artifacts
//!   (JAX + Pallas, AOT-lowered) execute through PJRT from rust;
//! * **L3** — the gradients are written into 4 simulated NetDAM devices
//!   and ring-allreduced by in-memory packet programs
//!   (`reduce → guarded_write → store`, §3), with the real gradient bits
//!   flowing through the DES;
//! * the loss curve is compared against the pure-python oracle
//!   (`artifacts/reference_curve.txt`) — deviation is reported and must
//!   stay at f32 noise level.
//!
//! ```sh
//! make artifacts && cargo run --release --example train_dataparallel
//! ```

use anyhow::Result;

fn main() -> Result<()> {
    let steps: usize = std::env::var("NETDAM_TRAIN_STEPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(50);
    let workers = 4;
    println!("== e2e: data-parallel MLP training over the NetDAM fabric ==");
    println!("workers: {workers}, steps: {steps}, optimizer: SGD via Pallas SIMD kernels\n");
    let curve = netdam::examples_support::train_dataparallel(steps, workers, true)?;
    let first = curve.first().copied().unwrap_or(f32::NAN);
    let last = curve.last().copied().unwrap_or(f32::NAN);
    println!("\nloss {first:.4} -> {last:.4} over {steps} steps");
    anyhow::ensure!(last < 0.8 * first, "training must reduce the loss");
    Ok(())
}
