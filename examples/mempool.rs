//! Memory pooling (paper §2.5/§2.6): the SDN controller as MMU, block
//! interleaving, ACLs, and the incast experiment.
//!
//! ```sh
//! cargo run --release --example mempool
//! ```

use anyhow::Result;
use netdam::coordinator::{run_e3, E3Config};
use netdam::pool::{AllocError, InterleaveMap, SdnController};
use netdam::wire::DeviceIp;

fn main() -> Result<()> {
    println!("== NetDAM global memory pool ==\n");

    // 4 × 2 GB devices → one 8 GB pool, 8 KiB interleave blocks.
    let devices: Vec<DeviceIp> = (1..=4).map(DeviceIp::lan).collect();
    let map = InterleaveMap::paper_default(devices.clone());
    let mut ctl = SdnController::new(map, 2 << 30);
    println!(
        "pool capacity: {:.1} GiB across {} devices",
        ctl.capacity() as f64 / (1 << 30) as f64,
        devices.len()
    );

    // Tenant 1 allocates 1 MiB; see how it spreads.
    let alloc = ctl.malloc(1, 1 << 20, true)?;
    println!(
        "tenant 1 malloc(1 MiB) -> gva {:#x} (len {})",
        alloc.gva, alloc.len
    );
    let extents = ctl.access(1, alloc.gva, 64 << 10, true)?;
    let mut per_dev = std::collections::BTreeMap::new();
    for e in &extents {
        *per_dev.entry(e.device).or_insert(0u64) += e.len;
    }
    println!("first 64 KiB scatter:");
    for (dev, bytes) in &per_dev {
        println!("  {dev}: {bytes} B");
    }

    // ACL enforcement: tenant 2 cannot touch it; read-only rejects writes.
    match ctl.access(2, alloc.gva, 64, false) {
        Err(AllocError::Denied { .. }) => println!("tenant 2 access: denied (ACL)"),
        other => panic!("expected denial, got {other:?}"),
    }
    let ro = ctl.malloc(2, 8192, false)?;
    assert!(ctl.access(2, ro.gva, 8, true).is_err());
    println!("tenant 2 read-only region: writes denied\n");

    // The incast experiment (E3) on a live fabric.
    println!("== E3: incast — direct many-to-one vs interleaved pool ==");
    let r = run_e3(&E3Config::default())?;
    print!("{}", r.table.render());
    println!(
        "\ndirect incast: {} drops, {} retransmits; pool: {} drops, {} retransmits",
        r.direct_drops, r.direct_retransmits, r.pool_drops, r.pool_retransmits
    );
    Ok(())
}
