//! Memory pooling (paper §2.5/§2.6) as a *data plane*: the SDN
//! controller leases GVA ranges and programs every device IOMMU;
//! `MemClient` compiles GVA reads/writes into scatter-gather packet
//! plans; denials come back as device-issued wire NAKs; and the incast
//! experiment (E3) runs through the same pool path.
//!
//! ```sh
//! cargo run --release --example mempool          # full E3
//! NETDAM_BENCH_SMOKE=1 cargo run --release --example mempool
//! ```

use anyhow::Result;
use netdam::coordinator::{run_e3, E3Config};
use netdam::mem::{MemClient, MemError};
use netdam::net::{Cluster, LinkConfig, Topology};
use netdam::pool::{InterleaveMap, SdnController};
use netdam::sim::{fmt_ns, Engine};
use netdam::wire::DeviceIp;

fn main() -> Result<()> {
    println!("== NetDAM global memory pool ==\n");

    // 4 × 2 GB devices on one ToR → one 8 GB pool, 8 KiB interleave
    // blocks, driven from one client host.
    let t = Topology::star(0x3001, 4, 1, LinkConfig::dc_100g());
    let mut cl = t.cluster;
    let mut eng: Engine<Cluster> = Engine::new();
    let devices: Vec<DeviceIp> = (1..=4).map(DeviceIp::lan).collect();
    let map = InterleaveMap::paper_default(devices.clone());
    let mut ctl = SdnController::new(map, 2 << 30);
    println!(
        "pool capacity: {:.1} GiB across {} devices",
        ctl.capacity() as f64 / (1 << 30) as f64,
        devices.len()
    );

    // Tenant 1 leases 1 MiB; the controller programs every device IOMMU.
    ctl.grant_host(&mut cl, 1, DeviceIp::lan(101));
    let alloc = ctl.malloc_mapped(&mut cl, 1, 1 << 20, true)?;
    println!(
        "tenant 1 malloc(1 MiB) -> gva {:#x} (len {}), IOMMUs programmed",
        alloc.gva, alloc.len
    );
    let extents = ctl.access(1, alloc.gva, 64 << 10, true)?;
    let mut per_dev = std::collections::BTreeMap::new();
    for e in &extents {
        *per_dev.entry(e.device).or_insert(0u64) += e.len;
    }
    println!("first 64 KiB scatter:");
    for (dev, bytes) in &per_dev {
        println!("  {dev}: {bytes} B");
    }

    // The data plane: write/read through MemClient, on GVAs only.
    let client = MemClient::new(t.hosts[0], DeviceIp::lan(101), 1, ctl.map().clone());
    let payload: Vec<u8> = (0..64 << 10).map(|i| (i % 251) as u8).collect();
    let t0 = eng.now();
    client.write(&mut cl, &mut eng, alloc.gva, &payload)?;
    let t_write = eng.now() - t0;
    let t0 = eng.now();
    let back = client.read(&mut cl, &mut eng, alloc.gva, payload.len())?;
    let t_read = eng.now() - t0;
    assert_eq!(back, payload, "reassembled in GVA order");
    println!(
        "\n64 KiB pooled write in {}, read-back in {} (verified)",
        fmt_ns(t_write),
        fmt_ns(t_read)
    );

    // Enforcement happens on the devices: a read-only lease NAKs writes,
    // a foreign tenant is fenced, and a freed lease faults unmapped.
    let ro = ctl.malloc_mapped(&mut cl, 1, 8192, false)?;
    match client.write(&mut cl, &mut eng, ro.gva, &[1u8; 64]) {
        Err(MemError::Nak { device, reason, .. }) => {
            println!("read-only lease: write NAK'd by {device} ({reason})")
        }
        other => panic!("expected a device NAK, got {other:?}"),
    }
    ctl.free_mapped(&mut cl, 1, ro.gva)?;
    match client.read(&mut cl, &mut eng, ro.gva, 64) {
        Err(MemError::Nak { reason, .. }) => {
            println!("freed lease: read NAK'd ({reason})")
        }
        other => panic!("expected a device NAK, got {other:?}"),
    }

    // The incast experiment (E3) on a live fabric — through the pool.
    println!("\n== E3: incast — direct many-to-one vs interleaved pool ==");
    let smoke = std::env::var("NETDAM_BENCH_SMOKE").is_ok();
    let cfg = E3Config {
        bytes_per_sender: if smoke { 256 << 10 } else { 2 << 20 },
        ..Default::default()
    };
    let r = run_e3(&cfg)?;
    print!("{}", r.table.render());
    println!(
        "\ndirect incast: {} drops, {} retransmits; pool: {} drops, {} retransmits",
        r.direct_drops, r.direct_retransmits, r.pool_drops, r.pool_retransmits
    );
    Ok(())
}
